//! In-tree micro-benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this runner. It
//! does warmup, adaptive iteration-count calibration to a target time,
//! multiple measurement samples, and reports median/mean/p10/p90 — enough
//! for the §Perf before/after tracking and the paper-table regenerators.
//!
//! Set `SATA_BENCH_FAST=1` to shrink sample counts (CI smoke mode).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Sample {
    /// Benchmark id.
    pub name: String,
    /// Median ns per iteration.
    pub median_ns: f64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// 10th-percentile ns per iteration.
    pub p10_ns: f64,
    /// 90th-percentile ns per iteration.
    pub p90_ns: f64,
    /// Iterations per measured sample (calibrated).
    pub iters_per_sample: u64,
    /// Samples measured.
    pub samples: usize,
}

impl Sample {
    /// Print the one-line summary.
    pub fn print(&self) {
        println!(
            "bench {:<44} median {:>12}  mean {:>12}  p10 {:>12}  p90 {:>12}  ({} iters x {} samples)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters_per_sample,
            self.samples
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// One `report_metric` record, kept so [`Bench::emit_snapshot`] can write
/// a machine-readable perf trajectory next to the printed table.
#[derive(Clone, Debug)]
pub struct Metric {
    /// Metric key, e.g. `serve.cim.warm.jobs_per_s`.
    pub key: String,
    /// Metric value.
    pub value: f64,
    /// Unit label, e.g. `jobs/s`.
    pub unit: String,
}

/// Benchmark runner; collects samples for a final summary table.
pub struct Bench {
    fast: bool,
    target_sample: Duration,
    /// Every sample measured so far (summary table input).
    pub results: Vec<Sample>,
    /// Every metric reported so far (snapshot input).
    pub metrics: Vec<Metric>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Whether `SATA_BENCH_FAST` asks for smoke mode. Only the *value*
/// decides: `0` and the empty string mean OFF (so `SATA_BENCH_FAST=0
/// cargo bench` runs the full-size bench), anything else set means ON.
/// Benches branch on this for their own job-count sizing so the whole
/// binary agrees with [`Bench::new`]'s sample sizing.
pub fn fast_mode() -> bool {
    fast_mode_value(std::env::var("SATA_BENCH_FAST").ok().as_deref())
}

/// Value parse behind [`fast_mode`], split out so it is unit-testable
/// without racing other tests on the process environment.
fn fast_mode_value(v: Option<&str>) -> bool {
    match v {
        None => false,
        Some(s) => {
            let s = s.trim();
            !s.is_empty() && s != "0"
        }
    }
}

impl Bench {
    /// Runner with `SATA_BENCH_FAST`-aware sample sizing (see
    /// [`fast_mode`] for how the variable is interpreted).
    pub fn new() -> Self {
        let fast = fast_mode();
        Bench {
            fast,
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(120)
            },
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Measure `f`, which must consume/produce observable work. Use
    /// `std::hint::black_box` inside to defeat constant folding.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Sample {
        // Warmup + calibration: find iters such that one sample ~ target.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target_sample.as_nanos() as f64 / per) as u64).max(1);
                break;
            }
            iters *= 4;
        }

        let n_samples = if self.fast { 5 } else { 12 };
        let mut per_iter = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }

        let s = Sample {
            name: name.to_string(),
            median_ns: stats::percentile(&per_iter, 50.0),
            mean_ns: stats::mean(&per_iter),
            p10_ns: stats::percentile(&per_iter, 10.0),
            p90_ns: stats::percentile(&per_iter, 90.0),
            iters_per_sample: iters,
            samples: n_samples,
        };
        s.print();
        self.results.push(s.clone());
        s
    }

    /// Print a `name: value` line that table-regenerator benches use for
    /// paper-figure rows (kept distinct from timing samples), and record
    /// it for [`Bench::emit_snapshot`].
    pub fn report_metric(&mut self, key: &str, value: f64, unit: &str) {
        println!("metric {key:<52} {value:>14.4} {unit}");
        self.metrics.push(Metric {
            key: key.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// Machine-readable snapshot of every sample and metric reported so
    /// far. The shape is pinned by the `bench_snapshots` schema test:
    /// top-level `name` / `fast` / `samples` / `metrics`, with each
    /// sample carrying the [`Sample`] fields and each metric the
    /// [`Metric`] fields.
    pub fn snapshot_json(&self, name: &str) -> Json {
        let samples = self
            .results
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("median_ns", Json::num(s.median_ns)),
                    ("mean_ns", Json::num(s.mean_ns)),
                    ("p10_ns", Json::num(s.p10_ns)),
                    ("p90_ns", Json::num(s.p90_ns)),
                    ("iters_per_sample", Json::num(s.iters_per_sample as f64)),
                    ("samples", Json::num(s.samples as f64)),
                ])
            })
            .collect();
        let metrics = self
            .metrics
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("key", Json::str(&m.key)),
                    ("value", Json::num(m.value)),
                    ("unit", Json::str(&m.unit)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(name)),
            ("fast", Json::Bool(self.fast)),
            ("samples", Json::Arr(samples)),
            ("metrics", Json::Arr(metrics)),
        ])
    }

    /// Write the [`Bench::snapshot_json`] snapshot to `BENCH_<name>.json`
    /// at the repo root (resolved from the crate manifest dir so `cargo
    /// bench` lands it in the same place regardless of cwd). Every bench
    /// calls this last; CI fails if the file stops appearing.
    pub fn emit_snapshot(&self, name: &str) -> std::io::Result<PathBuf> {
        let path = snapshot_path(name);
        std::fs::write(&path, self.snapshot_json(name).emit())?;
        println!("snapshot {}", path.display());
        Ok(path)
    }
}

/// Repo-root path where the `BENCH_<name>.json` snapshot for `name`
/// lives (both the committed baseline and fresh `emit_snapshot` output).
pub fn snapshot_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(format!("BENCH_{name}.json"))
}

/// Tolerance band for one metric unit: which direction counts as a
/// regression and how much drift is forgiven before `sata bench-diff`
/// flags it. The slack for a baseline value `b` is `rel * |b| + abs`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    /// Falling below `baseline - slack` is a regression (throughput-like).
    pub lower_bad: bool,
    /// Rising above `baseline + slack` is a regression (latency-like).
    pub higher_bad: bool,
    /// Relative slack, as a fraction of `|baseline|`.
    pub rel: f64,
    /// Absolute slack added on top of the relative component.
    pub abs: f64,
}

/// The per-unit tolerance policy behind `sata bench-diff`. Bands are
/// deliberately wide — benchmarks run on shared, noisy CI machines —
/// so the gate catches trajectory-sized regressions (a lock back on the
/// hot path, a cache that stopped hitting), not single-digit jitter.
pub fn band_for(unit: &str) -> Band {
    if unit.ends_with("/s") {
        // Throughput (jobs/s, req/s, tok/s): only a drop is bad.
        Band { lower_bad: true, higher_bad: false, rel: 0.5, abs: 0.0 }
    } else if unit == "x" {
        // Gain multipliers: only shrinking toward 1x is bad.
        Band { lower_bad: true, higher_bad: false, rel: 0.4, abs: 0.0 }
    } else if unit.starts_with("ns") || unit == "ms" {
        // Latency (ns, ns/tok, ns/step, ms): only growth is bad.
        Band { lower_bad: false, higher_bad: true, rel: 0.5, abs: 0.0 }
    } else if unit == "frac" {
        // Rates in [0, 1]: drift either way is suspicious; a relative
        // band would be meaningless near 0, so the slack is absolute.
        Band { lower_bad: true, higher_bad: true, rel: 0.0, abs: 0.25 }
    } else {
        // Counts (evictions, ...) and future units: two-sided, generous,
        // with a flat allowance so a baseline of 0 tolerates small noise.
        Band { lower_bad: true, higher_bad: true, rel: 0.5, abs: 1.0 }
    }
}

/// Verdict for one metric key compared across two snapshots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffStatus {
    /// Within the tolerance band.
    Ok,
    /// Outside the band in the bad direction — fails the gate.
    Regressed,
    /// In the baseline but absent from the fresh snapshot — fails the
    /// gate (a metric silently disappearing is itself drift).
    MissingInFresh,
    /// Only in the fresh snapshot — advisory; commit a new baseline.
    AddedInFresh,
    /// Value check skipped: the two snapshots were taken in different
    /// `SATA_BENCH_FAST` modes (smoke vs full sizing), so values are
    /// not comparable. Only the key structure was audited.
    SkippedFastMismatch,
}

/// One metric key compared between a committed baseline snapshot and a
/// freshly emitted one.
#[derive(Clone, Debug)]
pub struct MetricDiff {
    /// Metric key, e.g. `hot_path.k0.9.w4.ws.jobs_per_s`.
    pub key: String,
    /// Unit label (decides the tolerance [`Band`]).
    pub unit: String,
    /// Committed baseline value (NaN when [`DiffStatus::AddedInFresh`]).
    pub baseline: f64,
    /// Fresh value (NaN when [`DiffStatus::MissingInFresh`]).
    pub fresh: f64,
    /// The verdict.
    pub status: DiffStatus,
}

impl MetricDiff {
    /// One table line for the `bench-diff` report.
    pub fn render(&self) -> String {
        let tag = match self.status {
            DiffStatus::Ok => "ok",
            DiffStatus::Regressed => "REGRESSED",
            DiffStatus::MissingInFresh => "MISSING",
            DiffStatus::AddedInFresh => "added",
            DiffStatus::SkippedFastMismatch => "skipped",
        };
        format!(
            "  {tag:<9} {:<52} base {:>14.4} fresh {:>14.4} {}",
            self.key, self.baseline, self.fresh, self.unit
        )
    }
}

/// Result of diffing one `BENCH_<name>.json` pair.
#[derive(Clone, Debug)]
pub struct SnapshotDiff {
    /// Snapshot name (from the baseline's `name` field).
    pub name: String,
    /// Whether values were compared. False when the `fast` flags of the
    /// two snapshots disagree — then only key presence was checked.
    pub values_compared: bool,
    /// Per-key verdicts, baseline order first, then fresh-only keys.
    pub diffs: Vec<MetricDiff>,
}

impl SnapshotDiff {
    /// Gate failures: regressions plus keys missing from the fresh run.
    pub fn failures(&self) -> usize {
        self.diffs
            .iter()
            .filter(|d| {
                matches!(d.status, DiffStatus::Regressed | DiffStatus::MissingInFresh)
            })
            .count()
    }
}

fn metric_rows(snap: &Json) -> Result<Vec<(String, f64, String)>, String> {
    let arr = snap
        .get("metrics")
        .as_arr()
        .ok_or_else(|| "snapshot has no `metrics` array".to_string())?;
    arr.iter()
        .map(|m| {
            let key = m
                .get("key")
                .as_str()
                .ok_or_else(|| "metric without a string `key`".to_string())?
                .to_string();
            let value = m
                .get("value")
                .as_f64()
                .ok_or_else(|| format!("metric {key} without a numeric `value`"))?;
            let unit = m.get("unit").as_str().unwrap_or("").to_string();
            Ok((key, value, unit))
        })
        .collect()
}

/// Compare a fresh snapshot against its committed baseline. Value
/// comparisons apply the per-unit [`band_for`] tolerance and only run
/// when both snapshots were taken in the same `fast` mode; key-presence
/// checks always run. Errors only on malformed snapshots.
pub fn diff_snapshots(baseline: &Json, fresh: &Json) -> Result<SnapshotDiff, String> {
    let name = baseline.get("name").as_str().unwrap_or("?").to_string();
    let values_compared = baseline.get("fast").as_bool().unwrap_or(false)
        == fresh.get("fast").as_bool().unwrap_or(false);
    let base_rows = metric_rows(baseline)?;
    let fresh_rows = metric_rows(fresh)?;
    let fresh_by_key: std::collections::HashMap<&str, f64> =
        fresh_rows.iter().map(|(k, v, _)| (k.as_str(), *v)).collect();

    let mut diffs = Vec::with_capacity(base_rows.len());
    for (key, base, unit) in &base_rows {
        let (fresh_v, status) = match fresh_by_key.get(key.as_str()) {
            None => (f64::NAN, DiffStatus::MissingInFresh),
            Some(&f) if !values_compared => (f, DiffStatus::SkippedFastMismatch),
            Some(&f) => {
                let b = band_for(unit);
                let slack = b.rel * base.abs() + b.abs;
                let bad = (b.lower_bad && f < base - slack)
                    || (b.higher_bad && f > base + slack);
                (f, if bad { DiffStatus::Regressed } else { DiffStatus::Ok })
            }
        };
        diffs.push(MetricDiff {
            key: key.clone(),
            unit: unit.clone(),
            baseline: *base,
            fresh: fresh_v,
            status,
        });
    }
    for (key, value, unit) in &fresh_rows {
        if !base_rows.iter().any(|(k, _, _)| k == key) {
            diffs.push(MetricDiff {
                key: key.clone(),
                unit: unit.clone(),
                baseline: f64::NAN,
                fresh: *value,
                status: DiffStatus::AddedInFresh,
            });
        }
    }
    Ok(SnapshotDiff { name, values_compared, diffs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SATA_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn snapshot_shape_holds_fields() {
        std::env::set_var("SATA_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        b.run("s1", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        b.report_metric("m.one", 1.5, "jobs/s");
        let j = b.snapshot_json("unit");
        assert_eq!(j.get("name").as_str(), Some("unit"));
        assert!(j.get("fast").as_bool().is_some());
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 1);
        let m = &j.get("metrics").as_arr().unwrap()[0];
        assert_eq!(m.get("key").as_str(), Some("m.one"));
        assert_eq!(m.get("value").as_f64(), Some(1.5));
        assert_eq!(m.get("unit").as_str(), Some("jobs/s"));
        // Round-trips through the parser.
        let back = Json::parse(&j.emit()).unwrap();
        assert_eq!(back.emit(), j.emit());
    }

    #[test]
    fn fast_mode_parses_the_value_not_just_presence() {
        // Regression: `is_ok()` treated SATA_BENCH_FAST=0 (and empty) as
        // fast mode. Off: unset, empty, whitespace, and literal "0".
        assert!(!fast_mode_value(None));
        assert!(!fast_mode_value(Some("")));
        assert!(!fast_mode_value(Some("  ")));
        assert!(!fast_mode_value(Some("0")));
        assert!(!fast_mode_value(Some(" 0 ")));
        // On: any other set value.
        assert!(fast_mode_value(Some("1")));
        assert!(fast_mode_value(Some("true")));
        assert!(fast_mode_value(Some("00"))); // not the literal "0"
    }

    fn snap(fast: bool, metrics: &[(&str, f64, &str)]) -> Json {
        let rows = metrics
            .iter()
            .map(|(k, v, u)| {
                Json::obj(vec![
                    ("key", Json::str(k)),
                    ("value", Json::num(*v)),
                    ("unit", Json::str(u)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str("unit")),
            ("fast", Json::Bool(fast)),
            ("samples", Json::Arr(Vec::new())),
            ("metrics", Json::Arr(rows)),
        ])
    }

    #[test]
    fn bands_are_one_sided_for_throughput_and_latency() {
        for unit in ["jobs/s", "req/s", "tok/s", "x"] {
            let b = band_for(unit);
            assert!(b.lower_bad && !b.higher_bad, "{unit}");
        }
        for unit in ["ns", "ns/tok", "ns/step", "ms"] {
            let b = band_for(unit);
            assert!(b.higher_bad && !b.lower_bad, "{unit}");
        }
        let frac = band_for("frac");
        assert!(frac.lower_bad && frac.higher_bad && frac.rel == 0.0);
        let other = band_for("evictions");
        assert!(other.lower_bad && other.higher_bad && other.abs >= 1.0);
    }

    #[test]
    fn diff_flags_regressions_in_the_bad_direction_only() {
        let base = snap(
            false,
            &[
                ("t.jobs", 100.0, "jobs/s"),
                ("t.lat", 1000.0, "ns"),
                ("t.hit", 0.9, "frac"),
            ],
        );
        // Throughput up + latency down + hit-rate inside the band: clean.
        let good = snap(
            false,
            &[
                ("t.jobs", 160.0, "jobs/s"),
                ("t.lat", 400.0, "ns"),
                ("t.hit", 0.8, "frac"),
            ],
        );
        let d = diff_snapshots(&base, &good).unwrap();
        assert!(d.values_compared);
        assert_eq!(d.failures(), 0);
        assert!(d.diffs.iter().all(|m| m.status == DiffStatus::Ok));

        // Throughput halved-and-then-some, latency doubled-and-then-some,
        // hit rate off by more than the absolute band: three failures.
        let bad = snap(
            false,
            &[
                ("t.jobs", 49.0, "jobs/s"),
                ("t.lat", 1501.0, "ns"),
                ("t.hit", 0.6, "frac"),
            ],
        );
        let d = diff_snapshots(&base, &bad).unwrap();
        assert_eq!(d.failures(), 3);
        assert!(d.diffs.iter().all(|m| m.status == DiffStatus::Regressed));
    }

    #[test]
    fn diff_tracks_missing_and_added_keys() {
        let base = snap(false, &[("a", 1.0, "x"), ("b", 2.0, "x")]);
        let fresh = snap(false, &[("a", 1.0, "x"), ("c", 3.0, "x")]);
        let d = diff_snapshots(&base, &fresh).unwrap();
        let by_key = |k: &str| d.diffs.iter().find(|m| m.key == k).unwrap();
        assert_eq!(by_key("a").status, DiffStatus::Ok);
        // A vanished metric is a gate failure; a new one is advisory.
        assert_eq!(by_key("b").status, DiffStatus::MissingInFresh);
        assert_eq!(by_key("c").status, DiffStatus::AddedInFresh);
        assert_eq!(d.failures(), 1);
        assert!(by_key("b").render().contains("MISSING"));
    }

    #[test]
    fn fast_mismatch_skips_values_but_still_audits_keys() {
        let base = snap(false, &[("a", 100.0, "jobs/s"), ("b", 2.0, "x")]);
        // Smoke run: wildly lower throughput, but fast=true so values are
        // not comparable — only the missing key fails the gate.
        let fresh = snap(true, &[("a", 1.0, "jobs/s")]);
        let d = diff_snapshots(&base, &fresh).unwrap();
        assert!(!d.values_compared);
        let by_key = |k: &str| d.diffs.iter().find(|m| m.key == k).unwrap();
        assert_eq!(by_key("a").status, DiffStatus::SkippedFastMismatch);
        assert_eq!(by_key("b").status, DiffStatus::MissingInFresh);
        assert_eq!(d.failures(), 1);
    }

    #[test]
    fn diff_rejects_malformed_snapshots() {
        let ok = snap(false, &[("a", 1.0, "x")]);
        let no_metrics = Json::obj(vec![("name", Json::str("x"))]);
        assert!(diff_snapshots(&no_metrics, &ok).is_err());
        assert!(diff_snapshots(&ok, &no_metrics).is_err());
        let bad_row = Json::parse(
            r#"{"name":"x","fast":false,"metrics":[{"value":1.0}]}"#,
        )
        .unwrap();
        assert!(diff_snapshots(&ok, &bad_row).is_err());
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
