//! In-tree micro-benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this runner. It
//! does warmup, adaptive iteration-count calibration to a target time,
//! multiple measurement samples, and reports median/mean/p10/p90 — enough
//! for the §Perf before/after tracking and the paper-table regenerators.
//!
//! Set `SATA_BENCH_FAST=1` to shrink sample counts (CI smoke mode).

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Sample {
    /// Benchmark id.
    pub name: String,
    /// Median ns per iteration.
    pub median_ns: f64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// 10th-percentile ns per iteration.
    pub p10_ns: f64,
    /// 90th-percentile ns per iteration.
    pub p90_ns: f64,
    /// Iterations per measured sample (calibrated).
    pub iters_per_sample: u64,
    /// Samples measured.
    pub samples: usize,
}

impl Sample {
    /// Print the one-line summary.
    pub fn print(&self) {
        println!(
            "bench {:<44} median {:>12}  mean {:>12}  p10 {:>12}  p90 {:>12}  ({} iters x {} samples)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters_per_sample,
            self.samples
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner; collects samples for a final summary table.
pub struct Bench {
    fast: bool,
    target_sample: Duration,
    /// Every sample measured so far (summary table input).
    pub results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Runner with `SATA_BENCH_FAST`-aware sample sizing.
    pub fn new() -> Self {
        let fast = std::env::var("SATA_BENCH_FAST").is_ok();
        Bench {
            fast,
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(120)
            },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which must consume/produce observable work. Use
    /// `std::hint::black_box` inside to defeat constant folding.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Sample {
        // Warmup + calibration: find iters such that one sample ~ target.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target_sample.as_nanos() as f64 / per) as u64).max(1);
                break;
            }
            iters *= 4;
        }

        let n_samples = if self.fast { 5 } else { 12 };
        let mut per_iter = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }

        let s = Sample {
            name: name.to_string(),
            median_ns: stats::percentile(&per_iter, 50.0),
            mean_ns: stats::mean(&per_iter),
            p10_ns: stats::percentile(&per_iter, 10.0),
            p90_ns: stats::percentile(&per_iter, 90.0),
            iters_per_sample: iters,
            samples: n_samples,
        };
        s.print();
        self.results.push(s.clone());
        s
    }

    /// Print a `name: value` line that table-regenerator benches use for
    /// paper-figure rows (kept distinct from timing samples).
    pub fn report_metric(&self, key: &str, value: f64, unit: &str) {
        println!("metric {key:<52} {value:>14.4} {unit}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SATA_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
