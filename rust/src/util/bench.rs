//! In-tree micro-benchmark harness (no `criterion` offline).
//!
//! `cargo bench` targets use `harness = false` and drive this runner. It
//! does warmup, adaptive iteration-count calibration to a target time,
//! multiple measurement samples, and reports median/mean/p10/p90 — enough
//! for the §Perf before/after tracking and the paper-table regenerators.
//!
//! Set `SATA_BENCH_FAST=1` to shrink sample counts (CI smoke mode).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Sample {
    /// Benchmark id.
    pub name: String,
    /// Median ns per iteration.
    pub median_ns: f64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// 10th-percentile ns per iteration.
    pub p10_ns: f64,
    /// 90th-percentile ns per iteration.
    pub p90_ns: f64,
    /// Iterations per measured sample (calibrated).
    pub iters_per_sample: u64,
    /// Samples measured.
    pub samples: usize,
}

impl Sample {
    /// Print the one-line summary.
    pub fn print(&self) {
        println!(
            "bench {:<44} median {:>12}  mean {:>12}  p10 {:>12}  p90 {:>12}  ({} iters x {} samples)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters_per_sample,
            self.samples
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// One `report_metric` record, kept so [`Bench::emit_snapshot`] can write
/// a machine-readable perf trajectory next to the printed table.
#[derive(Clone, Debug)]
pub struct Metric {
    /// Metric key, e.g. `serve.cim.warm.jobs_per_s`.
    pub key: String,
    /// Metric value.
    pub value: f64,
    /// Unit label, e.g. `jobs/s`.
    pub unit: String,
}

/// Benchmark runner; collects samples for a final summary table.
pub struct Bench {
    fast: bool,
    target_sample: Duration,
    /// Every sample measured so far (summary table input).
    pub results: Vec<Sample>,
    /// Every metric reported so far (snapshot input).
    pub metrics: Vec<Metric>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Whether `SATA_BENCH_FAST` asks for smoke mode. Only the *value*
/// decides: `0` and the empty string mean OFF (so `SATA_BENCH_FAST=0
/// cargo bench` runs the full-size bench), anything else set means ON.
/// Benches branch on this for their own job-count sizing so the whole
/// binary agrees with [`Bench::new`]'s sample sizing.
pub fn fast_mode() -> bool {
    fast_mode_value(std::env::var("SATA_BENCH_FAST").ok().as_deref())
}

/// Value parse behind [`fast_mode`], split out so it is unit-testable
/// without racing other tests on the process environment.
fn fast_mode_value(v: Option<&str>) -> bool {
    match v {
        None => false,
        Some(s) => {
            let s = s.trim();
            !s.is_empty() && s != "0"
        }
    }
}

impl Bench {
    /// Runner with `SATA_BENCH_FAST`-aware sample sizing (see
    /// [`fast_mode`] for how the variable is interpreted).
    pub fn new() -> Self {
        let fast = fast_mode();
        Bench {
            fast,
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(120)
            },
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Measure `f`, which must consume/produce observable work. Use
    /// `std::hint::black_box` inside to defeat constant folding.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Sample {
        // Warmup + calibration: find iters such that one sample ~ target.
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let per = dt.as_nanos().max(1) as f64 / iters as f64;
                iters = ((self.target_sample.as_nanos() as f64 / per) as u64).max(1);
                break;
            }
            iters *= 4;
        }

        let n_samples = if self.fast { 5 } else { 12 };
        let mut per_iter = Vec::with_capacity(n_samples);
        for _ in 0..n_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }

        let s = Sample {
            name: name.to_string(),
            median_ns: stats::percentile(&per_iter, 50.0),
            mean_ns: stats::mean(&per_iter),
            p10_ns: stats::percentile(&per_iter, 10.0),
            p90_ns: stats::percentile(&per_iter, 90.0),
            iters_per_sample: iters,
            samples: n_samples,
        };
        s.print();
        self.results.push(s.clone());
        s
    }

    /// Print a `name: value` line that table-regenerator benches use for
    /// paper-figure rows (kept distinct from timing samples), and record
    /// it for [`Bench::emit_snapshot`].
    pub fn report_metric(&mut self, key: &str, value: f64, unit: &str) {
        println!("metric {key:<52} {value:>14.4} {unit}");
        self.metrics.push(Metric {
            key: key.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// Machine-readable snapshot of every sample and metric reported so
    /// far. The shape is pinned by the `bench_snapshots` schema test:
    /// top-level `name` / `fast` / `samples` / `metrics`, with each
    /// sample carrying the [`Sample`] fields and each metric the
    /// [`Metric`] fields.
    pub fn snapshot_json(&self, name: &str) -> Json {
        let samples = self
            .results
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("median_ns", Json::num(s.median_ns)),
                    ("mean_ns", Json::num(s.mean_ns)),
                    ("p10_ns", Json::num(s.p10_ns)),
                    ("p90_ns", Json::num(s.p90_ns)),
                    ("iters_per_sample", Json::num(s.iters_per_sample as f64)),
                    ("samples", Json::num(s.samples as f64)),
                ])
            })
            .collect();
        let metrics = self
            .metrics
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("key", Json::str(&m.key)),
                    ("value", Json::num(m.value)),
                    ("unit", Json::str(&m.unit)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::str(name)),
            ("fast", Json::Bool(self.fast)),
            ("samples", Json::Arr(samples)),
            ("metrics", Json::Arr(metrics)),
        ])
    }

    /// Write the [`Bench::snapshot_json`] snapshot to `BENCH_<name>.json`
    /// at the repo root (resolved from the crate manifest dir so `cargo
    /// bench` lands it in the same place regardless of cwd). Every bench
    /// calls this last; CI fails if the file stops appearing.
    pub fn emit_snapshot(&self, name: &str) -> std::io::Result<PathBuf> {
        let path = snapshot_path(name);
        std::fs::write(&path, self.snapshot_json(name).emit())?;
        println!("snapshot {}", path.display());
        Ok(path)
    }
}

/// Repo-root path where the `BENCH_<name>.json` snapshot for `name`
/// lives (both the committed baseline and fresh `emit_snapshot` output).
pub fn snapshot_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(format!("BENCH_{name}.json"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("SATA_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn snapshot_shape_holds_fields() {
        std::env::set_var("SATA_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        b.run("s1", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        b.report_metric("m.one", 1.5, "jobs/s");
        let j = b.snapshot_json("unit");
        assert_eq!(j.get("name").as_str(), Some("unit"));
        assert!(j.get("fast").as_bool().is_some());
        assert_eq!(j.get("samples").as_arr().unwrap().len(), 1);
        let m = &j.get("metrics").as_arr().unwrap()[0];
        assert_eq!(m.get("key").as_str(), Some("m.one"));
        assert_eq!(m.get("value").as_f64(), Some(1.5));
        assert_eq!(m.get("unit").as_str(), Some("jobs/s"));
        // Round-trips through the parser.
        let back = Json::parse(&j.emit()).unwrap();
        assert_eq!(back.emit(), j.emit());
    }

    #[test]
    fn fast_mode_parses_the_value_not_just_presence() {
        // Regression: `is_ok()` treated SATA_BENCH_FAST=0 (and empty) as
        // fast mode. Off: unset, empty, whitespace, and literal "0".
        assert!(!fast_mode_value(None));
        assert!(!fast_mode_value(Some("")));
        assert!(!fast_mode_value(Some("  ")));
        assert!(!fast_mode_value(Some("0")));
        assert!(!fast_mode_value(Some(" 0 ")));
        // On: any other set value.
        assert!(fast_mode_value(Some("1")));
        assert!(fast_mode_value(Some("true")));
        assert!(fast_mode_value(Some("00"))); // not the literal "0"
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
