//! Work-stealing execution pool. The serving pipeline's planned units
//! used to flow through one bounded `sync_channel`, so every execute
//! worker serialized on the same channel lock per unit. [`ExecPool`]
//! replaces it with the classic work-stealing shape, built from std
//! primitives only:
//!
//! * a shared **injector** queue where producers (plan workers) push,
//! * one **deque** per execute worker, popped LIFO by its owner,
//! * randomized, seeded **stealing**: an idle worker sweeps the other
//!   deques in a per-worker pseudorandom order and takes half of the
//!   first non-empty victim (oldest units first).
//!
//! A worker touches shared state only when its own deque runs dry: it
//! then grabs a small batch from the injector (amortizing the shared
//! lock over several units, and parking the extras on its own deque) or
//! steals. In steady state most pops are own-deque pops — uncontended
//! per-worker locks — which is what `CoordinatorMetrics`'s
//! `queue_lockfree_ratio` measures.
//!
//! Capacity and shutdown reproduce the `sync_channel` contract the pool
//! replaces: `push` blocks while `cap` units are in flight and errors
//! once every worker is gone; `next` returns `None` once every producer
//! handle has dropped **and** the pool has drained. Producers and
//! workers are RAII handles ([`Producer`], [`Worker`]) so a panicking
//! thread still participates in shutdown via `Drop`. All waits are
//! bounded (`wait_timeout` + re-check), so a notification lost to a
//! steal racing a shutdown costs a millisecond-scale delay, never a
//! hang — and no path ever holds two deque locks at once.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::Duration;

use crate::util::rng::{mix64, Rng};
use crate::util::sync::lock_tolerant;

/// Units grabbed from the injector per visit: the first is returned,
/// the rest park on the visiting worker's own deque.
const INJECTOR_GRAB: usize = 4;

/// How long an idle worker sleeps between full re-scans.
const IDLE_WAIT: Duration = Duration::from_millis(1);

/// How long a blocked producer sleeps between capacity re-checks.
const FULL_WAIT: Duration = Duration::from_millis(5);

/// Snapshot of an [`ExecPool`]'s contention counters. Every unit
/// returned by a pop is classified by where it came from, so
/// `local_pops + injector_pops + steal_successes` equals the number of
/// units handed to workers (and equals `pushes + requeues` once
/// drained — a requeued unit re-enters the pool and is handed out a
/// second time).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Units accepted by `push`.
    pub pushes: u64,
    /// Units re-entered via [`Worker::requeue`] after a failed attempt.
    pub requeues: u64,
    /// Pops served from the worker's own deque (no shared lock).
    pub local_pops: u64,
    /// Pops served directly from the shared injector.
    pub injector_pops: u64,
    /// Steal probes of another worker's deque.
    pub steal_attempts: u64,
    /// Probes that took at least one unit (each returns exactly one
    /// unit directly; extras park on the thief's deque).
    pub steal_successes: u64,
    /// Total units moved off victims by steals, extras included.
    pub stolen_items: u64,
}

impl PoolCounters {
    /// Units handed to workers so far.
    pub fn returns(&self) -> u64 {
        self.local_pops + self.injector_pops + self.steal_successes
    }

    /// Fraction of handed-out units served from the worker's own deque
    /// without touching shared queue state. 0 when nothing popped yet.
    pub fn local_ratio(&self) -> f64 {
        let total = self.returns();
        if total == 0 { 0.0 } else { self.local_pops as f64 / total as f64 }
    }
}

struct Counters {
    pushes: AtomicU64,
    requeues: AtomicU64,
    local_pops: AtomicU64,
    injector_pops: AtomicU64,
    steal_attempts: AtomicU64,
    steal_successes: AtomicU64,
    stolen_items: AtomicU64,
}

/// The shared pool. Create with [`ExecPool::new`], then hand a
/// [`Producer`] to each pushing thread and a [`Worker`] (one per `id in
/// 0..workers`) to each popping thread.
pub struct ExecPool<T> {
    injector: Mutex<VecDeque<T>>,
    deques: Vec<Mutex<VecDeque<T>>>,
    /// Parking lot for both blocked producers and idle workers. Holds
    /// no data — it exists so waits can re-check the atomics under a
    /// lock and sleep with a bounded timeout.
    signal: Mutex<()>,
    work_cv: Condvar,
    space_cv: Condvar,
    cap: usize,
    /// Units pushed but not yet handed to a worker.
    pending: AtomicUsize,
    producers: AtomicUsize,
    consumers: AtomicUsize,
    /// Set once the last producer drops; with `pending == 0` it means
    /// drained-and-done.
    closed: AtomicBool,
    seed: u64,
    counters: Counters,
}

impl<T> ExecPool<T> {
    /// Pool for exactly `workers` consumers (ids `0..workers`), holding
    /// at most `cap` in-flight units, stealing in a `seed`-derived
    /// per-worker order. `workers` and `cap` are clamped to ≥ 1.
    pub fn new(workers: usize, cap: usize, seed: u64) -> Self {
        let workers = workers.max(1);
        ExecPool {
            injector: Mutex::new(VecDeque::new()),
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            signal: Mutex::new(()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            cap: cap.max(1),
            pending: AtomicUsize::new(0),
            producers: AtomicUsize::new(0),
            consumers: AtomicUsize::new(workers),
            closed: AtomicBool::new(false),
            seed,
            counters: Counters {
                pushes: AtomicU64::new(0),
                requeues: AtomicU64::new(0),
                local_pops: AtomicU64::new(0),
                injector_pops: AtomicU64::new(0),
                steal_attempts: AtomicU64::new(0),
                steal_successes: AtomicU64::new(0),
                stolen_items: AtomicU64::new(0),
            },
        }
    }

    /// Register a producer handle. All producers must be registered
    /// before the first one drops, or the pool closes early.
    pub fn producer(self: &Arc<Self>) -> Producer<T> {
        self.producers.fetch_add(1, Ordering::AcqRel);
        Producer { pool: Arc::clone(self) }
    }

    /// The worker handle for deque `id` (`id < workers`; one handle per
    /// id — the pool counted its consumers at construction and each
    /// handle's drop retires one).
    pub fn worker(self: &Arc<Self>, id: usize) -> Worker<T> {
        assert!(id < self.deques.len(), "worker id out of range");
        let rng = Rng::new(mix64(self.seed ^ (id as u64).wrapping_add(1)));
        Worker { pool: Arc::clone(self), id, rng }
    }

    /// Contention counters so far.
    pub fn counters(&self) -> PoolCounters {
        PoolCounters {
            pushes: self.counters.pushes.load(Ordering::Relaxed),
            requeues: self.counters.requeues.load(Ordering::Relaxed),
            local_pops: self.counters.local_pops.load(Ordering::Relaxed),
            injector_pops: self.counters.injector_pops.load(Ordering::Relaxed),
            steal_attempts: self.counters.steal_attempts.load(Ordering::Relaxed),
            steal_successes: self
                .counters
                .steal_successes
                .load(Ordering::Relaxed),
            stolen_items: self.counters.stolen_items.load(Ordering::Relaxed),
        }
    }

    /// Units pushed but not yet handed to a worker.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::Acquire)
    }

    /// Number of worker deques (the pool's parallelism).
    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    fn push(&self, item: T) -> Result<(), T> {
        loop {
            if self.consumers.load(Ordering::Acquire) == 0 {
                // Every worker is gone: nothing will ever drain this.
                return Err(item);
            }
            let p = self.pending.load(Ordering::Acquire);
            if p < self.cap {
                // Reserve the slot with a CAS so the bound is hard even
                // under concurrent producers.
                if self
                    .pending
                    .compare_exchange(
                        p,
                        p + 1,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
                {
                    break;
                }
                continue;
            }
            // Full: sleep until a unit retires. Bounded wait + re-check
            // bounds the cost of a missed notification.
            let g = lock_tolerant(&self.signal);
            if self.pending.load(Ordering::Acquire) >= self.cap
                && self.consumers.load(Ordering::Acquire) > 0
            {
                let _ = self
                    .space_cv
                    .wait_timeout(g, FULL_WAIT)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        }
        lock_tolerant(&self.injector).push_back(item);
        self.counters.pushes.fetch_add(1, Ordering::Relaxed);
        self.work_cv.notify_one();
        Ok(())
    }

    /// A unit left the queueing structure: free its capacity slot.
    fn retire_one(&self) {
        self.pending.fetch_sub(1, Ordering::AcqRel);
        self.space_cv.notify_one();
    }

    fn pop(&self, worker: usize, rng: &mut Rng) -> Option<T> {
        loop {
            // 1. Own deque, newest first — LIFO keeps a session's
            //    just-planned units hot in the worker running them.
            {
                let mut own = lock_tolerant(&self.deques[worker]);
                if let Some(item) = own.pop_back() {
                    drop(own);
                    self.counters.local_pops.fetch_add(1, Ordering::Relaxed);
                    self.retire_one();
                    return Some(item);
                }
            }
            // 2. Shared injector: grab a small batch, return the oldest,
            //    park the rest locally (amortizes the shared lock).
            let batch: Vec<T> = {
                let mut inj = lock_tolerant(&self.injector);
                let take = INJECTOR_GRAB.min(inj.len());
                inj.drain(..take).collect()
            };
            let mut it = batch.into_iter();
            if let Some(first) = it.next() {
                let extras = it.len();
                if extras > 0 {
                    lock_tolerant(&self.deques[worker]).extend(it);
                }
                self.counters.injector_pops.fetch_add(1, Ordering::Relaxed);
                self.retire_one();
                return Some(first);
            }
            // 3. Steal: sweep the other deques in a seeded pseudorandom
            //    order; take half of the first non-empty victim, oldest
            //    units first. One victim lock at a time, released before
            //    the thief touches its own deque — deque locks never
            //    nest.
            let n = self.deques.len();
            if n > 1 {
                let offset = rng.gen_range(n - 1);
                for i in 0..n {
                    let v = (worker + 1 + offset + i) % n;
                    if v == worker {
                        continue;
                    }
                    self.counters
                        .steal_attempts
                        .fetch_add(1, Ordering::Relaxed);
                    let booty: Vec<T> = {
                        let mut victim = lock_tolerant(&self.deques[v]);
                        let take = victim.len().div_ceil(2);
                        victim.drain(..take).collect()
                    };
                    let mut it = booty.into_iter();
                    if let Some(first) = it.next() {
                        let extras = it.len();
                        self.counters
                            .steal_successes
                            .fetch_add(1, Ordering::Relaxed);
                        self.counters
                            .stolen_items
                            .fetch_add(1 + extras as u64, Ordering::Relaxed);
                        if extras > 0 {
                            lock_tolerant(&self.deques[worker]).extend(it);
                        }
                        self.retire_one();
                        return Some(first);
                    }
                }
            }
            // 4. Nothing anywhere. Done if closed-and-drained (`closed`
            //    is read first: its Acquire load makes all prior pushes'
            //    `pending` increments visible to the check below), else
            //    sleep briefly and re-scan.
            if self.closed.load(Ordering::Acquire)
                && self.pending.load(Ordering::Acquire) == 0
            {
                return None;
            }
            let g = lock_tolerant(&self.signal);
            if self.closed.load(Ordering::Acquire)
                && self.pending.load(Ordering::Acquire) == 0
            {
                return None;
            }
            let _ = self
                .work_cv
                .wait_timeout(g, IDLE_WAIT)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// RAII producer handle. Dropping the last one closes the pool: workers
/// drain whatever is pending, then their `next` returns `None`.
pub struct Producer<T> {
    pool: Arc<ExecPool<T>>,
}

impl<T> Producer<T> {
    /// Push a unit, blocking while the pool is at capacity. `Err`
    /// returns the unit if every worker is gone.
    pub fn push(&self, item: T) -> Result<(), T> {
        self.pool.push(item)
    }

    /// The pool this producer feeds.
    pub fn pool(&self) -> &Arc<ExecPool<T>> {
        &self.pool
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        if self.pool.producers.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.pool.closed.store(true, Ordering::Release);
            self.pool.work_cv.notify_all();
            self.pool.space_cv.notify_all();
        }
    }
}

/// RAII worker handle for one deque. Dropping it (return or panic)
/// retires the consumer; once none remain, blocked producers error out
/// instead of hanging.
pub struct Worker<T> {
    pool: Arc<ExecPool<T>>,
    id: usize,
    rng: Rng,
}

impl<T> Worker<T> {
    /// Next unit, or `None` once the pool is closed and drained.
    pub fn next(&mut self) -> Option<T> {
        self.pool.pop(self.id, &mut self.rng)
    }

    /// Return a unit this worker already popped back to the pool (the
    /// crash-tolerance requeue path: the attempt to process it died and
    /// a retry is owed). The unit lands on this worker's own deque and
    /// re-takes a `pending` slot **bypassing the capacity CAS** — the
    /// requeuing thread is the consumer that would drain the pool, so
    /// blocking it on a full pool would deadlock. The transient
    /// `pending == cap + 1` overshoot is bounded by the number of
    /// concurrently requeueing workers and only delays producers, never
    /// loses a slot: the requeued unit retires its slot when re-popped.
    pub fn requeue(&self, item: T) {
        self.pool.pending.fetch_add(1, Ordering::AcqRel);
        lock_tolerant(&self.pool.deques[self.id]).push_back(item);
        self.pool.counters.requeues.fetch_add(1, Ordering::Relaxed);
        self.pool.work_cv.notify_one();
    }

    /// This worker's deque index.
    pub fn id(&self) -> usize {
        self.id
    }

    /// The pool this worker drains.
    pub fn pool(&self) -> &Arc<ExecPool<T>> {
        &self.pool
    }
}

impl<T> Drop for Worker<T> {
    fn drop(&mut self) {
        if self.pool.consumers.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last worker gone: wake blocked producers so they error.
            self.pool.space_cv.notify_all();
            self.pool.work_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex as StdMutex;

    #[test]
    fn single_worker_drains_everything_then_closes() {
        let pool = Arc::new(ExecPool::<u64>::new(1, 64, 7));
        let tx = pool.producer();
        let mut w = pool.worker(0);
        for i in 0..20u64 {
            tx.push(i).expect("worker alive");
        }
        drop(tx);
        let mut got: Vec<u64> = Vec::new();
        while let Some(x) = w.next() {
            got.push(x);
        }
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<u64>>());
        let c = pool.counters();
        assert_eq!(c.pushes, 20);
        assert_eq!(c.returns(), 20);
        assert_eq!(c.steal_attempts, 0);
        assert_eq!(pool.pending(), 0);
        // Closed and drained: further pops return None immediately.
        assert_eq!(w.next(), None);
    }

    /// Single-threaded, so the batch-grab / steal interleaving is fully
    /// deterministic: w0 grabs one injector batch (INJECTOR_GRAB = 4),
    /// then w1 drains the rest and steals w0's parked extras.
    #[test]
    fn steal_takes_half_oldest_first() {
        let pool = Arc::new(ExecPool::<u64>::new(2, 64, 42));
        let tx = pool.producer();
        let mut w0 = pool.worker(0);
        let mut w1 = pool.worker(1);
        for i in 0..8u64 {
            tx.push(i).expect("workers alive");
        }
        // w0: injector grab of [0,1,2,3] — returns 0, parks 1,2,3.
        assert_eq!(w0.next(), Some(0));
        // w1: injector grab of [4,5,6,7] — returns 4, parks 5,6,7 —
        // then drains its own deque LIFO.
        assert_eq!(w1.next(), Some(4));
        assert_eq!(w1.next(), Some(7));
        assert_eq!(w1.next(), Some(6));
        assert_eq!(w1.next(), Some(5));
        // w1 is dry: steals ceil(3/2) = 2 of w0's [1,2,3], oldest
        // first — returns 1, parks 2.
        assert_eq!(w1.next(), Some(1));
        assert_eq!(w1.next(), Some(2));
        // Last steal takes the final unit.
        assert_eq!(w1.next(), Some(3));
        drop(tx);
        assert_eq!(w0.next(), None);
        assert_eq!(w1.next(), None);
        let c = pool.counters();
        assert_eq!(c.pushes, 8);
        assert_eq!(c.returns(), 8);
        assert_eq!(c.steal_successes, 2);
        assert_eq!(c.stolen_items, 3);
        assert!(c.steal_attempts >= 2);
        assert_eq!(c.local_pops, 4); // 7,6,5 and the parked 2
        assert_eq!(c.injector_pops, 2); // the two batch grabs
    }

    #[test]
    fn capacity_one_still_transfers_everything() {
        let pool = Arc::new(ExecPool::<u64>::new(2, 1, 3));
        let tx = pool.producer();
        let mut handles = Vec::new();
        let got = Arc::new(StdMutex::new(Vec::<u64>::new()));
        for id in 0..2 {
            let mut w = pool.worker(id);
            let got = Arc::clone(&got);
            handles.push(std::thread::spawn(move || {
                while let Some(x) = w.next() {
                    got.lock().unwrap().push(x);
                }
            }));
        }
        // Producer blocks on the 1-slot cap most of the time; every
        // unit must still arrive exactly once.
        for i in 0..100u64 {
            tx.push(i).expect("workers alive");
        }
        drop(tx);
        for h in handles {
            h.join().expect("worker thread");
        }
        let mut got = Arc::try_unwrap(got).unwrap().into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn push_errors_once_all_workers_are_gone() {
        let pool = Arc::new(ExecPool::<u64>::new(1, 4, 1));
        let tx = pool.producer();
        let w = pool.worker(0);
        drop(w);
        assert_eq!(tx.push(9), Err(9));
    }

    #[test]
    fn close_wakes_idle_workers() {
        let pool = Arc::new(ExecPool::<u64>::new(2, 4, 5));
        let tx = pool.producer();
        let mut handles = Vec::new();
        for id in 0..2 {
            let mut w = pool.worker(id);
            handles.push(std::thread::spawn(move || {
                let mut n = 0u64;
                while w.next().is_some() {
                    n += 1;
                }
                n
            }));
        }
        tx.push(1).expect("workers alive");
        drop(tx); // close while workers may be mid-wait
        let total: u64 =
            handles.into_iter().map(|h| h.join().expect("worker")).sum();
        assert_eq!(total, 1);
    }

    /// A requeued unit comes back to the same worker and the counters
    /// balance as `returns == pushes + requeues` once drained — the
    /// conservation law the chaos tests lean on.
    #[test]
    fn requeue_hands_the_unit_back_and_balances_counters() {
        let pool = Arc::new(ExecPool::<u64>::new(1, 2, 11));
        let tx = pool.producer();
        let mut w = pool.worker(0);
        tx.push(1).expect("worker alive");
        tx.push(2).expect("worker alive");
        drop(tx);
        let first = w.next().expect("unit available");
        // Pretend processing `first` died: give it back.
        w.requeue(first);
        let mut got = Vec::new();
        while let Some(x) = w.next() {
            got.push(x);
        }
        got.sort_unstable();
        assert_eq!(got.len(), 2, "requeued unit is handed out again");
        assert_eq!(got, vec![1, 2]);
        let c = pool.counters();
        assert_eq!(c.pushes, 2);
        assert_eq!(c.requeues, 1);
        assert_eq!(c.returns(), c.pushes + c.requeues);
        assert_eq!(pool.pending(), 0);
    }

    /// Requeue never blocks, even when the pool sits exactly at its
    /// capacity bound (the requeuing worker is the drain — blocking it
    /// would deadlock).
    #[test]
    fn requeue_bypasses_the_capacity_bound() {
        let pool = Arc::new(ExecPool::<u64>::new(1, 1, 13));
        let tx = pool.producer();
        let mut w = pool.worker(0);
        tx.push(7).expect("worker alive");
        let unit = w.next().expect("unit available");
        tx.push(8).expect("slot freed by the pop");
        // Pool is full again (pending == cap == 1); requeue must not
        // block on the bound.
        w.requeue(unit);
        assert_eq!(pool.pending(), 2, "transient overshoot is allowed");
        drop(tx);
        let mut got = Vec::new();
        while let Some(x) = w.next() {
            got.push(x);
        }
        got.sort_unstable();
        assert_eq!(got, vec![7, 8]);
        assert_eq!(pool.pending(), 0);
    }

    /// Many workers, tight cap, several seeds: units are conserved
    /// exactly through every steal/shutdown interleaving.
    #[test]
    fn stress_conserves_units_across_seeds() {
        for seed in [1u64, 7, 42] {
            let pool = Arc::new(ExecPool::<u64>::new(4, 8, seed));
            let got = Arc::new(StdMutex::new(Vec::<u64>::new()));
            let mut handles = Vec::new();
            for id in 0..4 {
                let mut w = pool.worker(id);
                let got = Arc::clone(&got);
                handles.push(std::thread::spawn(move || {
                    while let Some(x) = w.next() {
                        got.lock().unwrap().push(x);
                    }
                }));
            }
            let tx = pool.producer();
            for i in 0..300u64 {
                tx.push(i).expect("workers alive");
            }
            drop(tx);
            for h in handles {
                h.join().expect("worker thread");
            }
            let mut got =
                Arc::try_unwrap(got).unwrap().into_inner().unwrap();
            got.sort_unstable();
            assert_eq!(got, (0..300).collect::<Vec<u64>>(), "seed {seed}");
            let c = pool.counters();
            assert_eq!(c.pushes, 300);
            assert_eq!(c.returns(), 300);
            assert_eq!(pool.pending(), 0);
            assert!(c.local_ratio() <= 1.0);
        }
    }
}
