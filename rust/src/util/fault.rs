//! Seeded, deterministic fault injection for chaos testing.
//!
//! A [`FaultPlan`] describes *when to kill a worker*: at a worker's
//! *n*-th unit (per-worker kills) or at the *k*-th unit started anywhere
//! in the pipeline (global-ordinal kills). Workers consult the plan at
//! the **start** of each unit — before any state is mutated — via
//! [`FaultPlan::check_exec`] / [`FaultPlan::check_plan`]; a matching
//! rule fires exactly once and kills the caller with a `panic!`, which
//! the coordinator's `catch_unwind` isolation turns into a worker-death
//! + requeue event (see `crate::coordinator`).
//!
//! Determinism: every rule fires **at most once**, and a global-ordinal
//! rule fires on exactly the *k*-th unit start (the ordinal is claimed
//! by one atomic increment), so the *number* of fired kills — and hence
//! the coordinator's `worker_deaths` / `units_requeued` counters — is
//! reproducible run to run as long as the workload reaches the rule's
//! trigger point. Per-worker rules additionally pin *which worker* dies;
//! whether a given worker reaches its *n*-th unit can depend on
//! scheduling, so chaos tests assert on `fired()` rather than assuming
//! every per-worker rule triggers.
//!
//! This module deliberately lives in `util` (outside the lint's hot-path
//! modules): the kill itself is a `panic!`, which hot code is forbidden
//! from containing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::rng::{mix64, Rng};
use crate::util::sync::lock_tolerant;

/// Salt for [`FaultPlan::seeded`]'s (worker, nth) derivation stream.
const FAULT_SEED_SALT: u64 = 0x4641_554C_545F_494E; // "FAULT_IN"

/// One fired kill, recorded for audit/replay logs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Pipeline stage the kill hit (`"exec"` or `"plan"`).
    pub stage: &'static str,
    /// Worker id whose unit died.
    pub worker: usize,
    /// Global 1-based unit-start ordinal (within the stage) at which the
    /// kill fired.
    pub ordinal: u64,
}

/// Mutable trigger state: per-worker unit counts, one fired flag per
/// rule, and the event log. Guarded by one mutex (`fault_plan` — the
/// name is the lock-order manifest class in `crate::analysis::locks`,
/// kept even though this file itself is outside the linted hot set).
#[derive(Default)]
struct FaultState {
    exec_per_worker: HashMap<usize, u64>,
    exec_worker_fired: Vec<bool>,
    exec_global_fired: Vec<bool>,
    plan_global_fired: Vec<bool>,
    events: Vec<FaultEvent>,
}

/// A deterministic worker-kill schedule. Build with
/// [`FaultPlan::at_worker_units`], [`FaultPlan::at_global_units`],
/// [`FaultPlan::at_plan_jobs`], or [`FaultPlan::seeded`]; share via
/// `Arc` through `CoordinatorConfig::fault`.
pub struct FaultPlan {
    /// (worker id, 1-based nth unit started by that worker) exec kills.
    worker_kills: Vec<(usize, u64)>,
    /// 1-based global exec unit-start ordinals to kill.
    global_kills: Vec<u64>,
    /// 1-based global plan job-start ordinals to kill.
    plan_kills: Vec<u64>,
    /// Global exec unit-start counter (claimed before the rule check).
    exec_ordinal: AtomicU64,
    /// Global plan job-start counter.
    plan_ordinal: AtomicU64,
    fault_plan: Mutex<FaultState>,
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("worker_kills", &self.worker_kills)
            .field("global_kills", &self.global_kills)
            .field("plan_kills", &self.plan_kills)
            .field("fired", &self.fired())
            .finish()
    }
}

impl FaultPlan {
    fn with_rules(
        worker_kills: Vec<(usize, u64)>,
        global_kills: Vec<u64>,
        plan_kills: Vec<u64>,
    ) -> Self {
        let state = FaultState {
            exec_per_worker: HashMap::new(),
            exec_worker_fired: vec![false; worker_kills.len()],
            exec_global_fired: vec![false; global_kills.len()],
            plan_global_fired: vec![false; plan_kills.len()],
            events: Vec::new(),
        };
        FaultPlan {
            worker_kills,
            global_kills,
            plan_kills,
            exec_ordinal: AtomicU64::new(0),
            plan_ordinal: AtomicU64::new(0),
            fault_plan: Mutex::new(state),
        }
    }

    /// Kill each listed `(worker, nth)` point: execute worker `worker`
    /// dies at the start of the `nth` unit it picks up (1-based).
    pub fn at_worker_units(kills: &[(usize, u64)]) -> Self {
        Self::with_rules(kills.to_vec(), Vec::new(), Vec::new())
    }

    /// Kill the `k`-th unit started anywhere in the execute stage, for
    /// each listed 1-based ordinal `k`. Requeued units claim fresh
    /// ordinals, so ordinals keep advancing past a kill.
    pub fn at_global_units(ordinals: &[u64]) -> Self {
        Self::with_rules(Vec::new(), ordinals.to_vec(), Vec::new())
    }

    /// Kill the `k`-th job a plan worker starts planning, for each
    /// listed 1-based ordinal `k`.
    pub fn at_plan_jobs(ordinals: &[u64]) -> Self {
        Self::with_rules(Vec::new(), Vec::new(), ordinals.to_vec())
    }

    /// `count` seeded (worker, nth) exec kills over `workers` workers:
    /// the same `(seed, workers, count)` always derives the same kill
    /// points, with nth ∈ [1, 4] so kills land early in short runs.
    pub fn seeded(seed: u64, workers: usize, count: usize) -> Self {
        let workers = workers.max(1);
        let mut rng = Rng::new(mix64(seed ^ FAULT_SEED_SALT));
        let mut kills = Vec::with_capacity(count);
        for _ in 0..count {
            let w = rng.gen_range(workers);
            let nth = 1 + rng.gen_range(4) as u64;
            kills.push((w, nth));
        }
        Self::at_worker_units(&kills)
    }

    /// Consult the plan at the start of an execute unit on `worker`.
    /// Panics (killing the caller) if an unfired rule matches.
    pub fn check_exec(&self, worker: usize) {
        let ordinal = self.exec_ordinal.fetch_add(1, Ordering::SeqCst) + 1;
        let mut kill = false;
        {
            let mut st = lock_tolerant(&self.fault_plan);
            let count = st.exec_per_worker.entry(worker).or_insert(0);
            *count += 1;
            let nth = *count;
            for (i, &(w, n)) in self.worker_kills.iter().enumerate() {
                if w == worker && n == nth && !st.exec_worker_fired[i] {
                    st.exec_worker_fired[i] = true;
                    kill = true;
                    break;
                }
            }
            if !kill {
                for (i, &k) in self.global_kills.iter().enumerate() {
                    if k == ordinal && !st.exec_global_fired[i] {
                        st.exec_global_fired[i] = true;
                        kill = true;
                        break;
                    }
                }
            }
            if kill {
                st.events.push(FaultEvent { stage: "exec", worker, ordinal });
            }
        }
        if kill {
            panic!(
                "injected fault: killing exec worker {worker} at unit ordinal {ordinal}"
            );
        }
    }

    /// Consult the plan at the start of planning a job on plan worker
    /// `worker`. Panics (killing the caller) if an unfired rule matches.
    pub fn check_plan(&self, worker: usize) {
        let ordinal = self.plan_ordinal.fetch_add(1, Ordering::SeqCst) + 1;
        let mut kill = false;
        {
            let mut st = lock_tolerant(&self.fault_plan);
            for (i, &k) in self.plan_kills.iter().enumerate() {
                if k == ordinal && !st.plan_global_fired[i] {
                    st.plan_global_fired[i] = true;
                    kill = true;
                    break;
                }
            }
            if kill {
                st.events.push(FaultEvent { stage: "plan", worker, ordinal });
            }
        }
        if kill {
            panic!(
                "injected fault: killing plan worker {worker} at job ordinal {ordinal}"
            );
        }
    }

    /// How many rules have fired so far.
    pub fn fired(&self) -> usize {
        lock_tolerant(&self.fault_plan).events.len()
    }

    /// Total rules in the plan (the upper bound of [`FaultPlan::fired`]).
    pub fn planned(&self) -> usize {
        self.worker_kills.len() + self.global_kills.len() + self.plan_kills.len()
    }

    /// Every kill that has fired, in firing order.
    pub fn events(&self) -> Vec<FaultEvent> {
        lock_tolerant(&self.fault_plan).events.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn caught(f: impl FnOnce() + std::panic::UnwindSafe) -> bool {
        std::panic::catch_unwind(f).is_err()
    }

    #[test]
    fn worker_rule_fires_exactly_once_at_its_nth_unit() {
        let plan = FaultPlan::at_worker_units(&[(1, 2)]);
        assert!(!caught(|| plan.check_exec(1))); // 1st unit: survives
        assert!(!caught(|| plan.check_exec(0))); // other worker: survives
        assert!(caught(|| plan.check_exec(1))); // 2nd unit: dies
        assert!(!caught(|| plan.check_exec(1))); // rule spent
        assert_eq!(plan.fired(), 1);
        let ev = plan.events();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].stage, "exec");
        assert_eq!(ev[0].worker, 1);
    }

    #[test]
    fn global_rule_fires_on_the_kth_start_anywhere() {
        let plan = FaultPlan::at_global_units(&[3]);
        assert!(!caught(|| plan.check_exec(0)));
        assert!(!caught(|| plan.check_exec(1)));
        assert!(caught(|| plan.check_exec(2))); // 3rd start overall
        assert!(!caught(|| plan.check_exec(0)));
        assert_eq!(plan.fired(), 1);
        assert_eq!(plan.events()[0].ordinal, 3);
    }

    #[test]
    fn plan_stage_rules_are_independent_of_exec_rules() {
        let plan = FaultPlan::at_plan_jobs(&[1]);
        assert!(!caught(|| plan.check_exec(0))); // exec untouched
        assert!(caught(|| plan.check_plan(0)));
        assert!(!caught(|| plan.check_plan(0)));
        assert_eq!(plan.fired(), 1);
        assert_eq!(plan.events()[0].stage, "plan");
    }

    #[test]
    fn seeded_plans_are_reproducible_and_bounded() {
        let a = FaultPlan::seeded(42, 4, 3);
        let b = FaultPlan::seeded(42, 4, 3);
        assert_eq!(a.worker_kills, b.worker_kills);
        assert_eq!(a.planned(), 3);
        for &(w, n) in &a.worker_kills {
            assert!(w < 4);
            assert!((1..=4).contains(&n));
        }
        let c = FaultPlan::seeded(43, 4, 3);
        assert_ne!(a.worker_kills, c.worker_kills, "seeds must differ");
    }
}
