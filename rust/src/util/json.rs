//! Minimal JSON codec (parse + emit), dependency-free.
//!
//! The offline environment has no `serde` facade, so config files, trace
//! files, and the AOT `manifest.json` are handled by this small module.
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` handled for
//! the BMP); numbers parse as `f64` (sufficient: our files carry counts and
//! physical quantities).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. `Object` is a `BTreeMap` so emission is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (parsed as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (`BTreeMap` keeps emission stable).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------------ accessors
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key → value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access; returns `Null` for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ----------------------------------------------------------- builders
    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    /// String value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    /// Array of integers.
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Array of floats.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------- parsing
    /// Parse a complete JSON document (trailing data is an error).
    /// Documents nested deeper than [`MAX_DEPTH`] are rejected with a
    /// [`ParseError`] instead of recursing toward a stack overflow.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ emitting
    /// Compact single-line emission.
    pub fn emit(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.emit())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting depth [`Json::parse`] and [`Scanner`]
/// accept. A hostile deeply-nested document fails with an explicit
/// [`ParseError`] ("nesting too deep") instead of blowing the stack —
/// both the tree parser and the lazy scanner recurse per nesting level,
/// so the bound is the totality guarantee for `serve --traces-dir`.
pub const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => {
                self.enter()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'{') => {
                self.enter()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Advance past one well-formed value without building it — the lazy
    /// scanner's core. Shares the tokenizers (`string`, `number`, `lit`)
    /// with the tree path so accept/reject behavior is identical.
    fn skip_value(&mut self) -> Result<(), ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null).map(drop),
            Some(b't') => self.lit("true", Json::Bool(true)).map(drop),
            Some(b'f') => self.lit("false", Json::Bool(false)).map(drop),
            Some(b'"') => self.string().map(drop),
            Some(b'[') => {
                self.enter()?;
                let r = self.skip_array();
                self.depth -= 1;
                r
            }
            Some(b'{') => {
                self.enter()?;
                let r = self.skip_object();
                self.depth -= 1;
                r
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(drop),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn skip_array(&mut self) -> Result<(), ParseError> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.skip_value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn skip_object(&mut self) -> Result<(), ParseError> {
        self.eat(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            self.skip_value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Lazy scanner over raw JSON text: finds top-level object fields and
/// slices array elements as raw `&str` sub-slices **without building the
/// full [`Json`] tree** — the ingestion fast path for trace/model/session
/// files, whose bulk is deeply nested index arrays that the scanner
/// slices and converts to `usize` directly.
///
/// Totality contract: the scanner shares the tree parser's tokenizers and
/// [`MAX_DEPTH`] bound, so it accepts exactly the documents [`Json::parse`]
/// accepts (hostile files still yield a [`ParseError`], never a panic or
/// stack overflow), and the lazy loaders built on it are pinned equivalent
/// to the tree path by the `lazy_ingestion` property test.
pub struct Scanner<'a> {
    text: &'a str,
}

impl<'a> Scanner<'a> {
    /// Wrap `text`; no work happens until fields are requested.
    pub fn new(text: &'a str) -> Self {
        Scanner { text }
    }

    /// All top-level object fields as `(key, raw value slice)` pairs, last
    /// duplicate winning (matching `Obj`'s `BTreeMap` insert semantics).
    /// The whole document's syntax is validated — including trailing
    /// data — but field payloads are skipped, not built. A structurally
    /// valid **non-object** document yields an empty map, so callers
    /// report the same "missing field" errors the tree path would.
    pub fn top_fields(
        &self,
    ) -> Result<std::collections::BTreeMap<String, &'a str>, ParseError> {
        let b = self.text.as_bytes();
        let mut p = Parser { b, pos: 0, depth: 0 };
        p.ws();
        let mut map = std::collections::BTreeMap::new();
        if p.peek() != Some(b'{') {
            p.skip_value()?;
            p.ws();
            if p.pos != b.len() {
                return Err(p.err("trailing data"));
            }
            return Ok(map);
        }
        p.eat(b'{')?;
        p.enter()?;
        p.ws();
        if p.peek() == Some(b'}') {
            p.pos += 1;
        } else {
            loop {
                p.ws();
                let key = p.string()?;
                p.ws();
                p.eat(b':')?;
                p.ws();
                let start = p.pos;
                p.skip_value()?;
                map.insert(key, &self.text[start..p.pos]);
                p.ws();
                match p.peek() {
                    Some(b',') => p.pos += 1,
                    Some(b'}') => {
                        p.pos += 1;
                        break;
                    }
                    _ => return Err(p.err("expected ',' or '}'")),
                }
            }
        }
        p.ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(map)
    }

    /// Split a raw array slice (a [`Scanner::top_fields`] value or a
    /// previous `elements` element) into its element slices. `Ok(None)`
    /// when the value is well-formed but not an array — callers map that
    /// to the same type errors `Json::as_arr` would produce.
    pub fn elements(raw: &str) -> Result<Option<Vec<&str>>, ParseError> {
        let b = raw.as_bytes();
        let mut p = Parser { b, pos: 0, depth: 0 };
        p.ws();
        if p.peek() != Some(b'[') {
            return Ok(None);
        }
        p.eat(b'[')?;
        let mut out = Vec::new();
        p.ws();
        if p.peek() == Some(b']') {
            p.pos += 1;
        } else {
            loop {
                p.ws();
                let start = p.pos;
                p.skip_value()?;
                out.push(&raw[start..p.pos]);
                p.ws();
                match p.peek() {
                    Some(b',') => p.pos += 1,
                    Some(b']') => {
                        p.pos += 1;
                        break;
                    }
                    _ => return Err(p.err("expected ',' or ']'")),
                }
            }
        }
        Ok(Some(out))
    }

    /// A raw element slice as an exact non-negative integer — the value
    /// `Json::as_usize` would see, with a digits-only fast path that
    /// bypasses `f64` entirely (≤ 15 digits is exactly representable, so
    /// the fast and slow paths agree bit for bit).
    pub fn as_usize(raw: &str) -> Option<usize> {
        let t = raw.trim();
        if !t.is_empty() && t.len() <= 15 && t.bytes().all(|c| c.is_ascii_digit()) {
            return t.parse::<usize>().ok();
        }
        Json::parse(t).ok().and_then(|j| j.as_usize())
    }

    /// A raw element slice as a full [`Json`] value (for small scalar
    /// fields where tree construction is the cheap path).
    pub fn value(raw: &str) -> Result<Json, ParseError> {
        Json::parse(raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(*v.get("d"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn roundtrip_emit_parse() {
        let v = Json::obj(vec![
            ("name", Json::str("ttst")),
            ("n", Json::num(30.0)),
            ("vals", Json::arr_f64(&[1.5, 2.25, -3.0])),
            ("flag", Json::Bool(true)),
            ("nested", Json::obj(vec![("x", Json::Null)])),
        ]);
        let text = v.emit();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn integer_emission_has_no_fraction() {
        assert_eq!(Json::num(30.0).emit(), "30");
        assert_eq!(Json::num(0.5).emit(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escape_and_raw() {
        assert_eq!(
            Json::parse(r#""Aü""#).unwrap(),
            Json::Str("Aü".into())
        );
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn deep_nesting_is_rejected_not_overflowed() {
        // Well under the bound parses fine…
        let ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // …at/over the bound both paths fail with an explicit error.
        let deep = format!("{}0{}", "[".repeat(MAX_DEPTH + 10), "]".repeat(MAX_DEPTH + 10));
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.to_string().contains("nesting too deep"), "{e}");
        let e = Scanner::new(&deep).top_fields().unwrap_err();
        assert!(e.to_string().contains("nesting too deep"), "{e}");
        // A hostile megabyte of open brackets errors instead of recursing.
        let hostile = "[".repeat(1 << 20);
        assert!(Json::parse(&hostile).is_err());
        assert!(Scanner::new(&hostile).top_fields().is_err());
        // Objects count toward the same bound.
        let objs = format!(
            "{}1{}",
            r#"{"k":"#.repeat(MAX_DEPTH + 10),
            "}".repeat(MAX_DEPTH + 10)
        );
        assert!(Json::parse(&objs).unwrap_err().to_string().contains("deep"));
    }

    #[test]
    fn scanner_slices_fields_without_building_the_tree() {
        let text = r#" {"n": 16, "heads": [[0, 2], [1]], "model": "x"} "#;
        let fields = Scanner::new(text).top_fields().unwrap();
        assert_eq!(fields.get("n").copied(), Some("16"));
        assert_eq!(fields.get("model").copied(), Some(r#""x""#));
        let rows = Scanner::elements(fields["heads"]).unwrap().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            Scanner::elements(rows[0])
                .unwrap()
                .unwrap()
                .iter()
                .map(|e| Scanner::as_usize(e).unwrap())
                .collect::<Vec<_>>(),
            vec![0, 2]
        );
        // Non-array values slice to None, matching as_arr.
        assert_eq!(Scanner::elements("16").unwrap(), None);
        // Duplicate keys: last wins, like BTreeMap insertion.
        let dup = Scanner::new(r#"{"a": 1, "a": 2}"#).top_fields().unwrap();
        assert_eq!(dup["a"], "2");
        // Valid non-object documents yield an empty map…
        assert!(Scanner::new("[1, 2]").top_fields().unwrap().is_empty());
        // …and malformed ones fail exactly where the tree parser would.
        assert!(Scanner::new(r#"{"n": 16, "heads": [[[0,"#).top_fields().is_err());
        assert!(Scanner::new("{} trailing").top_fields().is_err());
    }

    #[test]
    fn scanner_as_usize_matches_tree_semantics() {
        assert_eq!(Scanner::as_usize("7"), Some(7));
        assert_eq!(Scanner::as_usize("1e3"), Some(1000));
        assert_eq!(Scanner::as_usize("1.5"), None);
        assert_eq!(Scanner::as_usize("-1"), None);
        assert_eq!(Scanner::as_usize(r#""7""#), None);
        assert_eq!(Scanner::as_usize("[7]"), None);
        // 15-digit fast path agrees with the f64 path.
        assert_eq!(
            Scanner::as_usize("999999999999999"),
            Json::parse("999999999999999").unwrap().as_usize()
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"artifacts": [{"entry": "mha", "file": "mha.hlo.txt",
            "inputs": [{"name": "x", "shape": [64, 64], "dtype": "f32"}],
            "config": {"n_tokens": 64, "n_heads": 4}}]}"#;
        let v = Json::parse(text).unwrap();
        let a = &v.get("artifacts").as_arr().unwrap()[0];
        assert_eq!(a.get("entry").as_str(), Some("mha"));
        assert_eq!(a.get("config").get("n_heads").as_usize(), Some(4));
        assert_eq!(
            a.get("inputs").as_arr().unwrap()[0].get("shape").as_arr().unwrap()[0]
                .as_usize(),
            Some(64)
        );
    }
}
