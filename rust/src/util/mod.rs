//! Dependency-free infrastructure: RNG, JSON, stats, property testing, and
//! the benchmark harness. The offline build has only the `xla` crate's
//! closure available, so these stand in for `rand`/`serde_json`/`proptest`/
//! `criterion` respectively (see DESIGN.md).

pub mod arena;
pub mod bench;
pub mod deque;
pub mod fault;
pub mod json;
pub mod prop;
pub mod replay;
pub mod rng;
pub mod stats;
pub mod sync;
