//! Miniature property-testing harness (no `proptest` offline).
//!
//! `check` runs a property over many seeded RNG draws and, on failure,
//! reports the failing *seed* so the case replays exactly:
//!
//! ```rust,no_run
//! use sata::util::prop::check;
//! check("sorted order is a permutation", 200, |rng| {
//!     let n = 1 + rng.gen_range(64);
//!     // ... build inputs from rng, return Err(msg) on violation ...
//!     Ok(())
//! });
//! ```
//!
//! Coordinator/scheduler invariants use this throughout `rust/tests/`.

use super::rng::Rng;

/// Run `prop` with `iters` independently seeded RNGs; panic with the seed
/// and message on the first failure. Base seed is fixed for reproducibility
/// and can be overridden with `SATA_PROP_SEED`.
pub fn check<F>(name: &str, iters: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base: u64 = std::env::var("SATA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5A7A_2026);
    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at iteration {i} (seed {seed:#x}): {msg}\n\
                 replay with SATA_PROP_SEED={base} (case index {i})"
            );
        }
    }
}

/// Assert-style helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        check("trivial", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let v = rng.gen_range(100);
            if v < 1000 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn prop_assert_macro_shortcircuits() {
        check("macro", 5, |rng| {
            let v = rng.gen_range(10);
            prop_assert!(v < 10, "v out of range: {v}");
            Ok(())
        });
    }
}
