//! Checksummed record/replay logs (JSONL + trailer).
//!
//! A record log is a sequence of JSON lines (one [`Json`] value per
//! line) followed by a mandatory **end trailer** carrying the payload
//! line count and a running checksum of the payload text:
//!
//! ```text
//! {"kind":"config", ...}
//! {"kind":"job", ...}
//! ...
//! {"kind":"end","count":N,"checksum":"<16 hex digits>"}
//! ```
//!
//! [`LogWriter`] produces the format; [`parse_log`] validates it —
//! every line must parse (depth-bounded, see [`Json::parse`]), the
//! trailer must be present and last, and the recomputed checksum must
//! match. A truncated or tampered log is an explicit `Err(String)`,
//! never a panic, so `sata replay` can reject a bad artifact loudly.
//!
//! The checksum (`[line_hash]` folded over every payload line) is a
//! corruption tripwire, not a MAC: it catches truncation, bit rot, and
//! hand edits, which is what a determinism artifact needs.

use std::path::Path;

use crate::util::json::Json;
use crate::util::rng::mix64;

/// Non-zero seed so an empty log hashes to a distinctive value.
const LOG_HASH_SEED: u64 = 0x5245_504C_4159_4C47; // "REPLAYLG"

/// Order-sensitive 64-bit hash of one line's bytes ([`mix64`]-folded).
/// Also used by the serve recorder to digest per-job results.
pub fn line_hash(line: &str) -> u64 {
    let mut h = LOG_HASH_SEED;
    for b in line.bytes() {
        h = mix64(h ^ u64::from(b));
    }
    h
}

/// Fold one line's hash into the running log checksum.
fn fold(checksum: u64, line: &str) -> u64 {
    mix64(checksum ^ line_hash(line))
}

/// Render a u64 as the fixed-width hex string the trailer carries (JSON
/// `f64` numbers cannot hold a u64 exactly, so hashes travel as text).
pub fn hash_to_hex(h: u64) -> String {
    format!("{h:016x}")
}

/// Incremental log builder: `record` payload lines, `finish` appends the
/// trailer and returns the complete log text.
#[derive(Default)]
pub struct LogWriter {
    lines: Vec<String>,
    checksum: u64,
}

impl LogWriter {
    /// Empty log.
    pub fn new() -> Self {
        LogWriter { lines: Vec::new(), checksum: LOG_HASH_SEED }
    }

    /// Append one payload line.
    pub fn record(&mut self, line: Json) {
        let text = line.emit();
        self.checksum = fold(self.checksum, &text);
        self.lines.push(text);
    }

    /// Payload lines recorded so far.
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// Seal the log: append the end trailer and return the full text.
    pub fn finish(self) -> String {
        let end = Json::obj(vec![
            ("kind", Json::str("end")),
            ("count", Json::num(self.lines.len() as f64)),
            ("checksum", Json::str(&hash_to_hex(self.checksum))),
        ]);
        let mut out = self.lines.join("\n");
        if !out.is_empty() {
            out.push('\n');
        }
        out.push_str(&end.emit());
        out.push('\n');
        out
    }
}

/// Validate a sealed log and return its payload lines (trailer
/// excluded). Errors are explicit and name the failure: unparseable
/// line (including over-deep nesting), missing/misplaced/duplicated
/// trailer, count mismatch, checksum mismatch.
pub fn parse_log(text: &str) -> Result<Vec<Json>, String> {
    let mut payload = Vec::new();
    let mut checksum = LOG_HASH_SEED;
    let mut end: Option<(usize, String)> = None;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        if end.is_some() {
            return Err(format!(
                "replay log line {}: data after the end trailer (truncated \
                 or concatenated log?)",
                i + 1
            ));
        }
        let v = Json::parse(line)
            .map_err(|e| format!("replay log line {}: {e}", i + 1))?;
        if v.get("kind").as_str() == Some("end") {
            let count = v
                .get("count")
                .as_usize()
                .ok_or_else(|| "replay log trailer: missing 'count'".to_string())?;
            let sum = v
                .get("checksum")
                .as_str()
                .ok_or_else(|| "replay log trailer: missing 'checksum'".to_string())?
                .to_string();
            end = Some((count, sum));
            continue;
        }
        checksum = fold(checksum, line);
        payload.push(v);
    }
    let Some((count, sum)) = end else {
        return Err(
            "replay log has no end trailer (truncated recording?)".to_string()
        );
    };
    if count != payload.len() {
        return Err(format!(
            "replay log trailer count {count} != {} payload lines (truncated \
             or tampered log)",
            payload.len()
        ));
    }
    if sum != hash_to_hex(checksum) {
        return Err(format!(
            "replay log checksum mismatch: trailer {sum}, recomputed {} \
             (tampered log)",
            hash_to_hex(checksum)
        ));
    }
    Ok(payload)
}

/// Write a sealed log to disk.
pub fn write_log(path: &Path, text: &str) -> Result<(), String> {
    std::fs::write(path, text)
        .map_err(|e| format!("cannot write replay log {}: {e}", path.display()))
}

/// Read and validate a sealed log from disk.
pub fn read_log(path: &Path) -> Result<Vec<Json>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read replay log {}: {e}", path.display()))?;
    parse_log(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        let mut w = LogWriter::new();
        w.record(Json::obj(vec![("kind", Json::str("config")), ("jobs", Json::num(2.0))]));
        w.record(Json::obj(vec![("kind", Json::str("job")), ("id", Json::num(0.0))]));
        w.record(Json::obj(vec![("kind", Json::str("job")), ("id", Json::num(1.0))]));
        w.finish()
    }

    #[test]
    fn round_trip_preserves_payload() {
        let text = sample();
        let lines = parse_log(&text).expect("valid log must parse");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].get("kind").as_str(), Some("config"));
        assert_eq!(lines[2].get("id").as_usize(), Some(1));
    }

    #[test]
    fn empty_payload_logs_are_valid() {
        let text = LogWriter::new().finish();
        assert_eq!(parse_log(&text).expect("empty log is sealed"), vec![]);
    }

    #[test]
    fn truncation_is_an_explicit_error() {
        let text = sample();
        // Drop the trailer line entirely.
        let cut = text.lines().take(3).collect::<Vec<_>>().join("\n");
        let err = parse_log(&cut).expect_err("no trailer must fail");
        assert!(err.contains("end trailer"), "got: {err}");
        // Drop a payload line but keep the trailer: count mismatch.
        let mut lines: Vec<&str> = text.lines().collect();
        lines.remove(1);
        let err = parse_log(&lines.join("\n")).expect_err("count must fail");
        assert!(err.contains("count"), "got: {err}");
    }

    #[test]
    fn tampering_is_an_explicit_error() {
        let tampered = sample().replace("\"id\":1", "\"id\":7");
        let err = parse_log(&tampered).expect_err("edit must fail");
        assert!(err.contains("checksum mismatch"), "got: {err}");
        // A malformed payload line is a parse error, not a panic.
        let garbled = sample().replace("{\"id\":0,", "{\"id\":0");
        let err = parse_log(&garbled).expect_err("bad json must fail");
        assert!(err.contains("parse error"), "got: {err}");
    }

    #[test]
    fn data_after_trailer_is_rejected() {
        let mut text = sample();
        text.push_str("{\"kind\":\"job\",\"id\":9}\n");
        let err = parse_log(&text).expect_err("trailing data must fail");
        assert!(err.contains("after the end trailer"), "got: {err}");
    }

    #[test]
    fn deep_nesting_in_a_log_line_is_rejected_not_overflowed() {
        let bomb = format!("{}0{}", "[".repeat(100_000), "]".repeat(100_000));
        let mut text = String::new();
        text.push_str(&bomb);
        text.push('\n');
        let end = Json::obj(vec![
            ("kind", Json::str("end")),
            ("count", Json::num(1.0)),
            ("checksum", Json::str(&hash_to_hex(fold(LOG_HASH_SEED, &bomb)))),
        ]);
        text.push_str(&end.emit());
        let err = parse_log(&text).expect_err("depth bomb must fail");
        assert!(err.contains("deep"), "got: {err}");
    }
}
