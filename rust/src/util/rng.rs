//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline build environment provides no `rand` crate, and the simulator
//! needs *reproducible* randomness anyway (trace generation, Algo 1's random
//! seed key pick, property tests). Every consumer takes an explicit seed so
//! runs are replayable from the CLI (`--seed`).

/// SplitMix64 finalizer: a full-avalanche 64-bit bijection. Besides seed
/// expansion it is the mixing step of the mask/trace fingerprints and the
/// plan-cache keys (`mask::SelectiveMask::fingerprint`,
/// `engine::EngineOpts::cache_key`) — chaining `mix64(h ^ word)` gives a
/// position-sensitive 64-bit hash with no external hash crate.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    mix64(*state)
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = splitmix64(&mut sm);
        }
        // xoshiro must not start from the all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            // rejection zone keeps the distribution exactly uniform
            if lo >= n.wrapping_neg() % n || n.is_power_of_two() {
                return hi as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        // avoid ln(0)
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child RNG (for per-head / per-trace streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_injective_on_small_domain_and_avalanches() {
        // Bijectivity spot check: 4096 consecutive inputs, no collisions.
        let mut outs: Vec<u64> = (0..4096u64).map(mix64).collect();
        outs.sort_unstable();
        outs.dedup();
        assert_eq!(outs.len(), 4096);
        // Single-bit flips should flip ~half the output bits.
        let flipped = (mix64(0x1234_5678) ^ mix64(0x1234_5679)).count_ones();
        assert!((16..=48).contains(&flipped), "weak avalanche: {flipped} bits");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            let s = rng.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 8, "indices must be distinct");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
