//! Small statistics helpers shared by the simulator, metrics, and the
//! in-tree benchmark harness (no external stats crates offline).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted copy*; `p` in [0, 100].
///
/// Non-finite samples (NaN, ±∞) are dropped before ranking — the old
/// `partial_cmp(..).unwrap()` sort aborted on the first NaN; this matches
/// `LatencyHistogram::record`'s tolerance of degenerate samples. All-non-
/// finite (or empty) input reports 0.0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Geometric mean (used for "average gain across workloads" reporting,
/// matching how accelerator papers aggregate speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets.
/// Out-of-range samples clamp into the edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Inclusive lower bound of the range.
    pub lo: f64,
    /// Exclusive upper bound of the range.
    pub hi: f64,
    /// Per-bucket sample counts.
    pub counts: Vec<u64>,
    /// Total samples recorded.
    pub total: u64,
}

impl Histogram {
    /// Histogram over `[lo, hi)` with `bins` buckets.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    /// Record one sample (out-of-range clamps into the edge buckets).
    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Fraction of samples in bucket `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.total as f64
        }
    }

    /// Fold another histogram's counts into this one. Both sides must
    /// have the same shape (`lo`, `hi`, bucket count) — merging
    /// differently-binned histograms has no well-defined answer, so a
    /// mismatch panics rather than silently mis-binning.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            self.lo == other.lo
                && self.hi == other.hi
                && self.counts.len() == other.counts.len(),
            "Histogram::merge shape mismatch: [{}, {}) x{} vs [{}, {}) x{}",
            self.lo,
            self.hi,
            self.counts.len(),
            other.lo,
            other.hi,
            other.counts.len()
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
    }
}

/// Streaming log-bucketed latency histogram: O(1) memory regardless of
/// sample count, built for the coordinator's wall-latency percentiles
/// (p50/p95/p99) where keeping every sample would grow with traffic.
///
/// Buckets are geometric with [`LatencyHistogram::SUB_BUCKETS`] buckets
/// per octave (bucket width ≈ 9%), so a reported percentile is within
/// ~±4.5% of the exact sample value — plenty for serving dashboards.
/// Exact min/max are tracked so the tails never over-report.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LatencyHistogram {
    /// Buckets per factor-of-2; 8 → bucket edges grow by 2^(1/8) ≈ 1.09.
    pub const SUB_BUCKETS: usize = 8;
    /// Octaves covered starting at 1 (ns): 1 ns .. 2^64 ns (~584 years).
    const OCTAVES: usize = 64;

    /// Empty histogram covering 1 ns .. 2^64 ns.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; Self::OCTAVES * Self::SUB_BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(x: f64) -> usize {
        // log2(x) * SUB_BUCKETS, clamped; x <= 1 lands in bucket 0.
        let b = (x.max(1.0).log2() * Self::SUB_BUCKETS as f64) as usize;
        b.min(Self::OCTAVES * Self::SUB_BUCKETS - 1)
    }

    /// Record one sample (non-finite or negative samples count as 0).
    pub fn record(&mut self, x: f64) {
        let x = if x.is_finite() { x.max(0.0) } else { 0.0 };
        self.counts[Self::bucket(x)] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact arithmetic mean of the recorded samples.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Estimated percentile (`p` in [0, 100]): geometric midpoint of the
    /// bucket holding the rank-`p` sample, clamped to the exact min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        if rank >= self.total {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let mid = ((i as f64 + 0.5) / Self::SUB_BUCKETS as f64).exp2();
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another latency histogram into this one, as if every sample
    /// recorded into `other` had been recorded here instead: bucket
    /// counts, totals, and sums add; exact min/max widen. All
    /// `LatencyHistogram`s share one fixed geometric bucketing, so any
    /// two merge losslessly — after merging, `percentile` answers for
    /// the concatenated sample stream within the usual bucket
    /// resolution. This is the fleet-level rollup primitive: per-node
    /// coordinator histograms merge into one cluster-wide p50/p95/p99.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        let sd = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((sd - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_survives_nan_and_infinite_samples() {
        // NaN used to abort via partial_cmp().unwrap(); now it's dropped.
        assert_eq!(percentile(&[1.0, f64::NAN, 3.0], 50.0), 2.0);
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
        assert_eq!(
            percentile(&[f64::INFINITY, f64::NEG_INFINITY, f64::NAN], 99.0),
            0.0
        );
        // Finite samples rank as before around dropped ones.
        let xs = [f64::INFINITY, 4.0, 1.0, f64::NAN, 2.0, 3.0];
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        // Negative zero and negative values order correctly via total_cmp.
        assert_eq!(percentile(&[-1.0, -0.0, 0.0, 1.0], 0.0), -1.0);
    }

    #[test]
    fn geomean_of_gains() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn latency_histogram_tracks_percentiles_within_bucket_error() {
        let mut h = LatencyHistogram::new();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64 * 50.0).collect();
        for &x in &xs {
            h.record(x);
        }
        assert_eq!(h.count(), 1000);
        for p in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = h.percentile(p);
            assert!(
                (est / exact - 1.0).abs() < 0.10,
                "p{p}: est {est} vs exact {exact}"
            );
        }
        // Tails clamp to observed extremes (p100 is the exact max; p0 is
        // the lowest bucket's midpoint, within one bucket of the min).
        assert!(h.percentile(0.0) <= 55.0);
        assert_eq!(h.percentile(100.0), 50_000.0);
        assert!((h.mean() - mean(&xs)).abs() < 1e-6);
    }

    #[test]
    fn latency_histogram_empty_and_degenerate_samples() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        h.record(-3.0); // clamps to 0
        h.record(f64::NAN); // counts as 0
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(99.0), 0.0);
        h.record(100.0);
        h.record(10_000.0);
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile(100.0), 10_000.0);
        // low tail: lowest bucket's midpoint (~1 ns), clamped above min
        assert!(h.percentile(0.0) <= 1.1);
    }

    #[test]
    fn latency_histogram_merge_matches_concatenated_samples() {
        // Two disjoint shards of one sample stream: merging their
        // histograms must answer percentiles for the concatenation,
        // within the same bucket-resolution tolerance as direct
        // recording (the merged histogram IS the directly-recorded one:
        // bucket counts are additive, so equality is exact, not
        // approximate).
        let all: Vec<f64> = (1..=2000).map(|i| (i as f64) * 37.0).collect();
        let (lo_half, hi_half) = all.split_at(700);
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut direct = LatencyHistogram::new();
        for &x in lo_half {
            a.record(x);
        }
        for &x in hi_half {
            b.record(x);
        }
        for &x in &all {
            direct.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        assert!((a.mean() - direct.mean()).abs() < 1e-6);
        for p in [0.0, 10.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(
                a.percentile(p),
                direct.percentile(p),
                "p{p}: merged must equal direct recording exactly"
            );
            let exact = percentile(&all, p);
            let est = a.percentile(p);
            assert!(
                (est / exact - 1.0).abs() < 0.10,
                "p{p}: merged est {est} vs exact {exact}"
            );
        }
        // p100 stays the exact max across both shards.
        assert_eq!(a.percentile(100.0), 2000.0 * 37.0);
    }

    #[test]
    fn latency_histogram_merge_with_empty_is_identity() {
        let mut a = LatencyHistogram::new();
        for x in [10.0, 100.0, 1000.0] {
            a.record(x);
        }
        let before_p50 = a.percentile(50.0);
        a.merge(&LatencyHistogram::new());
        assert_eq!(a.count(), 3);
        assert_eq!(a.percentile(50.0), before_p50);
        assert_eq!(a.percentile(100.0), 1000.0);
        // Empty absorbing non-empty adopts its stats wholesale.
        let mut e = LatencyHistogram::new();
        e.merge(&a);
        assert_eq!(e.count(), 3);
        assert_eq!(e.percentile(100.0), 1000.0);
        assert_eq!(e.percentile(0.0), a.percentile(0.0));
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = Histogram::new(0.0, 10.0, 10);
        let mut b = Histogram::new(0.0, 10.0, 10);
        a.add(0.5);
        b.add(0.5);
        b.add(9.5);
        a.merge(&b);
        assert_eq!(a.counts[0], 2);
        assert_eq!(a.counts[9], 1);
        assert_eq!(a.total, 3);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn histogram_merge_rejects_mismatched_shapes() {
        let mut a = Histogram::new(0.0, 10.0, 10);
        let b = Histogram::new(0.0, 20.0, 10);
        a.merge(&b);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.9);
        h.add(-5.0); // clamps low
        h.add(50.0); // clamps high
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total, 4);
        assert!((h.frac(0) - 0.5).abs() < 1e-12);
    }
}
