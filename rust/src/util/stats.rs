//! Small statistics helpers shared by the simulator, metrics, and the
//! in-tree benchmark harness (no external stats crates offline).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation on a *sorted copy*; `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Geometric mean (used for "average gain across workloads" reporting,
/// matching how accelerator papers aggregate speedups).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets.
/// Out-of-range samples clamp into the edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Fraction of samples in bucket `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        let sd = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((sd - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn geomean_of_gains() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn histogram_buckets_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.9);
        h.add(-5.0); // clamps low
        h.add(50.0); // clamps high
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total, 4);
        assert!((h.frac(0) - 0.5).abs() < 1e-12);
    }
}
