//! Poison-tolerant locking. A worker that panics while holding a
//! [`Mutex`] poisons it, and every later `.lock().unwrap()` on the same
//! mutex turns into a *secondary* panic — one crashed job cascades into
//! a dead coordinator. The serving-path mutexes guard state that stays
//! sound across a panic (counter/CAS-based accounting, LRU maps, memo
//! caches: every update is applied atomically under the lock, never
//! left half-written across an unwind point that matters), so the right
//! policy is to **recover** the value and keep serving.
//!
//! [`lock_recover`] / [`get_mut_recover`] do exactly that, counting
//! each recovery into a caller-supplied [`AtomicUsize`] so the event is
//! observable (`CoordinatorMetrics::lock_recoveries`) instead of
//! silent; [`lock_tolerant`] is the uncounted form for state with no
//! metrics surface (the substrate baseline memo). Note a mutex stays
//! poisoned once poisoned, so the counters track recovery *events* —
//! every post-panic acquisition — not distinct panics.
//!
//! These helpers are also the tree's `lint`-sanctioned way to take a
//! serving-path lock: the panic-freedom lint (`src/analysis`) denies
//! bare `.lock().unwrap()` in hot-path modules, and the lock-discipline
//! lint understands `lock_recover(..)` acquisitions exactly like
//! `.lock()` ones.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{
    Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Lock `m`, recovering the value if a previous holder panicked.
/// Each recovery increments `recoveries` (relaxed; it is a statistic).
pub fn lock_recover<'a, T>(
    m: &'a Mutex<T>,
    recoveries: &AtomicUsize,
) -> MutexGuard<'a, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            recoveries.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

/// [`Mutex::get_mut`] with the same recovery policy as [`lock_recover`]
/// (exclusive access proves no lock is held, but poison is still
/// reported and must still be swallowed deliberately).
pub fn get_mut_recover<'a, T>(
    m: &'a mut Mutex<T>,
    recoveries: &AtomicUsize,
) -> &'a mut T {
    match m.get_mut() {
        Ok(v) => v,
        Err(poisoned) => {
            recoveries.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

/// Uncounted poison recovery, for mutexes with no metrics surface.
pub fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Shared-read lock on an [`RwLock`] with the same recovery policy as
/// [`lock_recover`]. An `RwLock` is poisoned only by a panicking
/// *writer*, so a recovered read still observes a value some writer
/// finished (or atomically abandoned) — the same soundness argument as
/// the mutex helpers.
pub fn read_recover<'a, T>(
    l: &'a RwLock<T>,
    recoveries: &AtomicUsize,
) -> RwLockReadGuard<'a, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => {
            recoveries.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

/// Exclusive-write lock on an [`RwLock`] with the same recovery policy
/// as [`lock_recover`].
pub fn write_recover<'a, T>(
    l: &'a RwLock<T>,
    recoveries: &AtomicUsize,
) -> RwLockWriteGuard<'a, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => {
            recoveries.fetch_add(1, Ordering::Relaxed);
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Poison `m` by panicking a thread that holds it.
    fn poison(m: &Arc<Mutex<u64>>) {
        let mc = Arc::clone(m);
        let t = std::thread::spawn(move || {
            let _g = mc.lock().unwrap();
            panic!("poison the mutex");
        });
        assert!(t.join().is_err());
        assert!(m.is_poisoned());
    }

    #[test]
    fn lock_recover_survives_poison_and_counts() {
        let m = Arc::new(Mutex::new(7u64));
        let n = AtomicUsize::new(0);
        // Healthy path: no recovery counted.
        *lock_recover(&m, &n) += 1;
        assert_eq!(n.load(Ordering::Relaxed), 0);
        poison(&m);
        // The value survives (updates are atomic under the lock) and
        // each post-poison acquisition counts one recovery.
        *lock_recover(&m, &n) += 1;
        assert_eq!(*lock_recover(&m, &n), 9);
        assert_eq!(n.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn get_mut_recover_survives_poison() {
        let m = Arc::new(Mutex::new(3u64));
        poison(&m);
        let mut m = Arc::try_unwrap(m).expect("sole owner");
        let n = AtomicUsize::new(0);
        *get_mut_recover(&mut m, &n) += 1;
        assert_eq!(*get_mut_recover(&mut m, &n), 4);
        assert_eq!(n.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn lock_tolerant_recovers_without_counting() {
        let m = Arc::new(Mutex::new(11u64));
        poison(&m);
        assert_eq!(*lock_tolerant(&m), 11);
    }

    #[test]
    fn rwlock_recover_survives_writer_poison_and_counts() {
        let l = Arc::new(RwLock::new(5u64));
        let n = AtomicUsize::new(0);
        // Healthy paths: no recovery counted.
        assert_eq!(*read_recover(&l, &n), 5);
        *write_recover(&l, &n) += 1;
        assert_eq!(n.load(Ordering::Relaxed), 0);
        // Poison via a panicking writer.
        let lc = Arc::clone(&l);
        let t = std::thread::spawn(move || {
            let _g = lc.write().unwrap();
            panic!("poison the rwlock");
        });
        assert!(t.join().is_err());
        assert!(l.is_poisoned());
        // Both guards recover the value and count the event.
        assert_eq!(*read_recover(&l, &n), 6);
        *write_recover(&l, &n) += 1;
        assert_eq!(*read_recover(&l, &n), 7);
        assert_eq!(n.load(Ordering::Relaxed), 3);
    }
}
