//! Crash-proof ingestion of hostile on-disk inputs: a traces directory
//! mixing valid, truncated, out-of-range-index, duplicate-index, and
//! wrong-row-count files must yield per-file errors and completed good
//! jobs — never a panic (the `TraceDir` iterator contract
//! `serve --traces-dir` relies on) — and a checkpoint directory
//! (`serve --checkpoint-dir D --resume`) gets the same treatment:
//! hostile files are per-file errors, the good checkpoints still
//! resume, and a resumed session is bitwise equal to a cold run. Plus
//! a property test that `MaskTrace::from_json` is total over
//! structurally-valid JSON with arbitrary index values.

use sata::config::SystemConfig;
use sata::coordinator::{Coordinator, Job};
use sata::mask::SelectiveMask;
use sata::trace::{MaskTrace, TraceDir};
use sata::util::json::Json;
use sata::util::prop::check;
use sata::util::rng::Rng;

fn good_trace(seed: u64) -> MaskTrace {
    let mut rng = Rng::new(seed);
    MaskTrace {
        model: "corpus".into(),
        n: 16,
        dk: 64,
        topk: 4,
        heads: (0..2).map(|_| SelectiveMask::random_topk(16, 4, &mut rng)).collect(),
    }
}

#[test]
fn bad_trace_corpus_completes_good_jobs_and_reports_per_file_errors() {
    let dir = std::env::temp_dir().join("sata_bad_trace_corpus");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // Two valid traces…
    good_trace(1).save(&dir.join("a_good.json")).unwrap();
    good_trace(2).save(&dir.join("b_good.json")).unwrap();
    // …and four hostile files: truncated JSON, an out-of-range key index
    // (used to abort the process via `from_topk_indices`' assert), a
    // duplicate index, and a wrong per-head row count.
    std::fs::write(dir.join("c_truncated.json"), r#"{"n": 16, "heads": [[[0,"#).unwrap();
    std::fs::write(
        dir.join("d_oob_index.json"),
        r#"{"model": "x", "n": 4, "dk": 8, "topk": 1, "heads": [[[9999],[0],[1],[2]]]}"#,
    )
    .unwrap();
    std::fs::write(
        dir.join("e_dup_index.json"),
        r#"{"n": 4, "heads": [[[1,1],[0],[2],[3]]]}"#,
    )
    .unwrap();
    std::fs::write(dir.join("f_wrong_rows.json"), r#"{"n": 4, "heads": [[[0],[1]]]}"#)
        .unwrap();

    // The serve shape: stream the dir, submit parsable traces, collect
    // per-file errors for the rest.
    let src = TraceDir::open(&dir).unwrap();
    assert_eq!(src.len(), 6);
    let coord = Coordinator::new(2, 4, SystemConfig::default());
    let mut submitted = 0usize;
    let mut file_errors = Vec::new();
    for (path, parsed) in src {
        match parsed {
            Ok(t) => {
                coord.submit(Job::new(submitted, t, None)).unwrap();
                submitted += 1;
            }
            Err(e) => file_errors.push((path, e)),
        }
    }
    let (results, metrics) = coord.drain();

    // Every good file became a completed job…
    assert_eq!(submitted, 2);
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(metrics.jobs_done, 2);
    assert_eq!(metrics.jobs_failed, 0);
    // …and every bad file produced a per-file error naming the problem.
    assert_eq!(file_errors.len(), 4);
    let err_for = |stem: &str| {
        file_errors
            .iter()
            .find(|(p, _)| p.file_name().unwrap().to_str().unwrap().starts_with(stem))
            .unwrap_or_else(|| panic!("no error for {stem}"))
            .1
            .clone()
    };
    assert!(err_for("c_truncated").contains("parse"), "{}", err_for("c_truncated"));
    assert!(err_for("d_oob_index").contains("out of range"), "{}", err_for("d_oob_index"));
    assert!(err_for("e_dup_index").contains("duplicate"), "{}", err_for("e_dup_index"));
    assert!(err_for("f_wrong_rows").contains("rows"), "{}", err_for("f_wrong_rows"));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deep_nesting_yields_per_file_errors_not_stack_overflow() {
    let dir = std::env::temp_dir().join("sata_deep_nesting");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    // An unbalanced megabyte of '[' and a balanced 100k-deep array bomb:
    // both must come back as ordinary per-file parse errors from the
    // recursion-depth bound, never a stack overflow (which would abort
    // the whole serve process, not one file).
    let deep = "[".repeat(1_000_000);
    std::fs::write(dir.join("g_deep.json"), format!(r#"{{"n": 4, "heads": {deep}"#))
        .unwrap();
    let bomb = format!("{}4{}", "[".repeat(100_000), "]".repeat(100_000));
    std::fs::write(
        dir.join("h_bomb.json"),
        format!(r#"{{"n": 4, "heads": [[{bomb}]]}}"#),
    )
    .unwrap();

    for name in ["g_deep.json", "h_bomb.json"] {
        let err = MaskTrace::load(&dir.join(name)).unwrap_err();
        assert!(
            err.contains("parse") && err.contains("deep"),
            "{name}: expected a depth-bound parse error, got: {err}"
        );
        let err = sata::decode::DecodeSession::load(&dir.join(name)).unwrap_err();
        assert!(
            err.contains("parse") && err.contains("deep"),
            "{name} (session path): expected a depth-bound parse error, got: {err}"
        );
    }

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lazy_ingestion_matches_tree_ingestion() {
    // The lazy `from_str` path (field scanner, no tree) must agree with
    // `from_json` (full tree) on every structurally-valid document —
    // same accept/reject decision, same parsed trace, same error text.
    check("lazy from_str == tree from_json", 80, |rng| {
        let n = 1 + rng.gen_range(10);
        let n_heads = rng.gen_range(4);
        let mut heads_json = Vec::new();
        for _ in 0..n_heads {
            let rows =
                if rng.chance(0.15) { n + 1 + rng.gen_range(3) } else { n };
            let rows_json = (0..rows)
                .map(|_| {
                    let count = rng.gen_range(n + 2);
                    Json::Arr(
                        (0..count)
                            .map(|_| {
                                let idx = if rng.chance(0.5) {
                                    rng.gen_range(n)
                                } else {
                                    rng.gen_range(3 * n + 2)
                                };
                                Json::num(idx as f64)
                            })
                            .collect(),
                    )
                })
                .collect();
            heads_json.push(Json::Arr(rows_json));
        }
        let j = Json::obj(vec![
            ("model", Json::str("prop")),
            ("n", Json::num(n as f64)),
            ("dk", Json::num(8.0)),
            ("topk", Json::num(2.0)),
            ("heads", Json::Arr(heads_json)),
        ]);
        let tree = MaskTrace::from_json(&j);
        let lazy = MaskTrace::from_str(&j.emit());
        match (&tree, &lazy) {
            (Ok(a), Ok(b)) => {
                if a.fingerprint() != b.fingerprint()
                    || a.model != b.model
                    || a.n != b.n
                    || a.dk != b.dk
                    || a.topk != b.topk
                {
                    Err("lazy and tree ingestion disagree on an accepted trace".into())
                } else {
                    Ok(())
                }
            }
            (Err(a), Err(b)) if a == b => Ok(()),
            (Err(a), Err(b)) => {
                Err(format!("error texts diverge: tree '{a}' vs lazy '{b}'"))
            }
            (Ok(_), Err(e)) => Err(format!("lazy rejected a tree-accepted trace: {e}")),
            (Err(e), Ok(_)) => Err(format!("lazy accepted a tree-rejected trace: {e}")),
        }
    });
}

#[test]
fn lazy_ingestion_matches_tree_for_models_and_sessions() {
    use sata::decode::DecodeSession;
    use sata::model::ModelTrace;
    use sata::trace::synth::{gen_models, gen_sessions};

    let spec = sata::config::WorkloadSpec::ttst();
    for (i, sess) in
        gen_sessions(&spec, 3, 2, 0.5, 5, 0.5, 99).into_iter().enumerate()
    {
        let j = sess.to_json();
        let tree = DecodeSession::from_json(&j).unwrap();
        let lazy = DecodeSession::from_str(&j.emit())
            .unwrap_or_else(|e| panic!("session {i}: lazy path rejected: {e}"));
        assert_eq!(lazy.fingerprint(), tree.fingerprint(), "session {i}");
    }
    for (i, model) in gen_models(&spec, 3, 3, 0.4, 7).into_iter().enumerate() {
        let j = model.to_json();
        let tree = ModelTrace::from_json(&j).unwrap();
        let lazy = ModelTrace::from_str(&j.emit())
            .unwrap_or_else(|e| panic!("model {i}: lazy path rejected: {e}"));
        assert_eq!(lazy.fingerprint(), tree.fingerprint(), "model {i}");
    }
}

#[test]
fn hostile_checkpoint_dir_resumes_good_sessions_and_reports_bad_files() {
    use sata::config::WorkloadSpec;
    use sata::coordinator::checkpoint::{capture_prefix, load_dir, sync_dir};
    use sata::coordinator::JobResult;
    use sata::trace::synth::gen_session;

    let dir = std::env::temp_dir().join("sata_bad_checkpoints");
    std::fs::remove_dir_all(&dir).ok();

    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    let session = gen_session(&spec, 2, 0.6, 3, 0.8, 21);
    // A genuinely partial prefix — prefill plus 1 of 3 decode steps —
    // so the resume below replans and re-executes only the remainder.
    let ck = capture_prefix(
        &session,
        &["sata".to_string()],
        "cim",
        &sys,
        spec.sf,
        true,
        true,
        1,
        0,
    )
    .expect("capture a valid prefix");
    let written = sync_dir(&dir, std::slice::from_ref(&ck), &[]).expect("sync");
    assert_eq!(written, vec![0]);

    // Hostile neighbours: truncated JSON, a depth bomb (caught by the
    // same `util::json` recursion bound the trace loader uses), and
    // valid JSON of the wrong kind.
    std::fs::write(
        dir.join("bad_truncated.json"),
        r#"{"kind": "session-checkpoint", "id"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad_deep.json"), "[".repeat(1_000_000)).unwrap();
    std::fs::write(dir.join("bad_schema.json"), r#"{"kind": "trace", "n": 4}"#)
        .unwrap();

    let (good, bad) = load_dir(&dir).expect("the dir itself is readable");
    assert_eq!(good.len(), 1, "the good checkpoint survives its neighbours");
    assert_eq!(good[0], ck, "the survivor round-trips bitwise");
    assert_eq!(bad.len(), 3, "one loud error per hostile file: {bad:?}");
    let err_for = |stem: &str| {
        bad.iter()
            .find(|e| e.contains(stem))
            .unwrap_or_else(|| panic!("no error names {stem}: {bad:?}"))
    };
    assert!(err_for("bad_truncated").contains("parse"), "{}", err_for("bad_truncated"));
    assert!(err_for("bad_deep").contains("deep"), "{}", err_for("bad_deep"));
    assert!(err_for("bad_schema").contains("kind"), "{}", err_for("bad_schema"));

    std::fs::remove_dir_all(&dir).ok();

    // Resume equivalence: attaching the surviving checkpoint must give
    // the exact result a cold run computes (wall-clock masked).
    let run = |ck: Option<sata::coordinator::checkpoint::SessionCheckpoint>| {
        let coord = Coordinator::new(1, 4, SystemConfig::for_workload(&spec));
        let mut job = Job::new(0, session.clone(), spec.sf);
        if let Some(ck) = ck {
            job = job.with_checkpoint(ck);
        }
        coord.submit(job).unwrap();
        let (mut results, _) = coord.drain();
        assert_eq!(results.len(), 1);
        let mut r: JobResult = results.pop().unwrap();
        assert!(r.is_ok(), "session must complete: {:?}", r.error);
        r.wall_ns = 0.0;
        r.to_json().emit()
    };
    assert_eq!(
        run(None),
        run(Some(good.into_iter().next().unwrap())),
        "a resumed session diverged from the cold run"
    );
}

#[test]
fn from_json_is_total_on_structurally_valid_json() {
    // Arbitrary index values (including far out of range), arbitrary
    // duplication, sometimes-wrong row counts: `from_json` must always
    // return Ok/Err — reaching the end of each iteration IS the property
    // (an assert inside mask construction would abort the test binary).
    check("from_json total over arbitrary indices", 80, |rng| {
        let n = 1 + rng.gen_range(10);
        let n_heads = rng.gen_range(4); // 0..=3 heads
        let mut all_valid = true;
        let mut heads_json = Vec::new();
        for _ in 0..n_heads {
            let rows = if rng.chance(0.15) {
                all_valid = false; // wrong row count
                n + 1 + rng.gen_range(3)
            } else {
                n
            };
            let mut rows_json = Vec::new();
            for _ in 0..rows {
                let count = rng.gen_range(n + 2);
                let mut seen = vec![false; 4 * n + 4];
                let mut row = Vec::new();
                for _ in 0..count {
                    // in range about half the time; sometimes huge
                    let idx = if rng.chance(0.5) {
                        rng.gen_range(n)
                    } else if rng.chance(0.1) {
                        1_000_000 + rng.gen_range(1000)
                    } else {
                        rng.gen_range(3 * n + 2)
                    };
                    if idx >= n || seen[idx.min(4 * n + 3)] {
                        all_valid = false;
                    }
                    if idx < seen.len() {
                        seen[idx] = true;
                    }
                    row.push(Json::num(idx as f64));
                }
                rows_json.push(Json::Arr(row));
            }
            heads_json.push(Json::Arr(rows_json));
        }
        let j = Json::obj(vec![
            ("model", Json::str("prop")),
            ("n", Json::num(n as f64)),
            ("dk", Json::num(8.0)),
            ("topk", Json::num(2.0)),
            ("heads", Json::Arr(heads_json)),
        ]);
        let res = MaskTrace::from_json(&j);
        match (all_valid, &res) {
            (true, Err(e)) => Err(format!("valid trace rejected: {e}")),
            (false, Ok(_)) => Err("invalid trace accepted".into()),
            _ => Ok(()),
        }
    });
}
