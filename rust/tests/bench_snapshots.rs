//! Perf-trajectory snapshot schema: every serving bench emits a
//! machine-readable `BENCH_<name>.json` at the repo root, and the
//! committed baselines must keep the exact shape `Bench::snapshot_json`
//! pins — top-level `name` / `fast` / `samples` / `metrics`, with the
//! `Sample` and `Metric` fields per element. CI re-runs the benches in
//! smoke mode and re-validates the freshly emitted files, so a bench
//! that stops emitting (or drifts from the schema) fails the build.

use sata::util::bench::{snapshot_path, Bench};
use sata::util::json::Json;

fn validate_snapshot(j: &Json, expect_name: &str) {
    assert_eq!(j.get("name").as_str(), Some(expect_name), "snapshot 'name' mismatch");
    assert!(j.get("fast").as_bool().is_some(), "missing boolean 'fast'");
    let samples = j.get("samples").as_arr().expect("'samples' must be an array");
    for s in samples {
        assert!(s.get("name").as_str().is_some(), "sample missing 'name'");
        for key in
            ["median_ns", "mean_ns", "p10_ns", "p90_ns", "iters_per_sample", "samples"]
        {
            let v = s.get(key).as_f64();
            assert!(v.is_some(), "sample missing numeric '{key}'");
            assert!(
                v.is_some_and(f64::is_finite),
                "sample '{key}' is not finite"
            );
        }
    }
    let metrics = j.get("metrics").as_arr().expect("'metrics' must be an array");
    for m in metrics {
        let key = m.get("key").as_str().expect("metric missing 'key'");
        assert!(!key.is_empty(), "metric has an empty 'key'");
        let value = m.get("value").as_f64().expect("metric missing numeric 'value'");
        assert!(value.is_finite(), "metric '{key}' value is not finite");
        let unit = m.get("unit").as_str().expect("metric missing 'unit'");
        assert!(!unit.is_empty(), "metric '{key}' has an empty 'unit'");
    }
    assert!(
        !samples.is_empty() || !metrics.is_empty(),
        "snapshot records neither samples nor metrics"
    );
}

#[test]
fn emitted_snapshot_round_trips_through_the_parser() {
    std::env::set_var("SATA_BENCH_FAST", "1");
    let mut b = Bench::new();
    let mut acc = 0u64;
    b.run("rt.sample", || {
        acc = std::hint::black_box(acc.wrapping_add(1));
    });
    b.report_metric("rt.metric", 2.5, "x");
    let path = b.emit_snapshot("unit_roundtrip").expect("emit snapshot");
    let text = std::fs::read_to_string(&path).expect("read snapshot back");
    let j = Json::parse(&text).expect("re-parse emitted snapshot");
    validate_snapshot(&j, "unit_roundtrip");
    std::fs::remove_file(&path).ok();
}

#[test]
fn committed_baselines_match_schema() {
    for name in
        ["serve", "decode_serve", "plan_delta", "model_serve", "cluster_serve", "hot_path"]
    {
        let path = snapshot_path(name);
        let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!(
                "{}: {e} — the perf trajectory requires a committed BENCH_{name}.json baseline at the repo root",
                path.display()
            )
        });
        let j = Json::parse(&text)
            .unwrap_or_else(|e| panic!("BENCH_{name}.json: {e}"));
        validate_snapshot(&j, name);
        assert!(
            !j.get("metrics").as_arr().unwrap().is_empty(),
            "BENCH_{name}.json carries no metrics"
        );
    }
}
