//! Deterministic chaos harness for crash-tolerant serving: seeded
//! worker kills ([`sata::util::fault::FaultPlan`]) across both exec
//! queue shapes and both deployment shapes (one coordinator, two-node
//! cluster), checking the three crash-tolerance invariants end to end:
//!
//! * **exactly-once resolution** — every submitted job yields exactly
//!   one result, whether it survived a kill or exhausted its budget;
//! * **unit conservation** — the work-stealing pool's pop counters
//!   account for every initial unit *plus* every crash requeue;
//! * **bitwise identity** — a disturbed run (kills within the retry
//!   budget) produces results byte-identical to an undisturbed run of
//!   the same seeded corpus, wall-clock aside: retries recompute, they
//!   never corrupt.

use std::sync::Arc;

use sata::cluster::{Cluster, ClusterConfig, RoutePolicy};
use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{
    Coordinator, CoordinatorConfig, CoordinatorMetrics, ExecQueueKind, Job,
    JobResult,
};
use sata::trace::synth::{gen_session, gen_traces};
use sata::util::fault::FaultPlan;

/// Mixed corpus: `n` single-unit model jobs plus one decode session
/// (1 prefill unit + 3 step units), all seeded — two runs see the
/// identical job stream.
fn corpus(spec: &WorkloadSpec, n: usize) -> Vec<Job> {
    let mut jobs: Vec<Job> = gen_traces(spec, n, 7)
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            Job::with_flows(i, t, spec.sf, vec!["sata".into(), "dense".into()])
        })
        .collect();
    jobs.push(Job::with_flows(
        n,
        gen_session(spec, 2, 0.6, 3, 0.8, 40),
        spec.sf,
        vec!["sata".into()],
    ));
    jobs
}

/// Execute units in `corpus(_, 6)`: six 1-unit model jobs + (1 + 3)
/// session units.
const CORPUS_JOBS: usize = 7;
const CORPUS_UNITS: usize = 10;

/// Wall-clock-masked emitted JSON per result, sorted by id — the
/// bitwise identity two runs are compared on.
fn digests(results: &[JobResult]) -> Vec<(usize, String)> {
    let mut out: Vec<(usize, String)> = results
        .iter()
        .map(|r| {
            let mut masked = r.clone();
            masked.wall_ns = 0.0;
            (r.id, masked.to_json().emit())
        })
        .collect();
    out.sort();
    out
}

/// Serve the corpus through one coordinator. One plan worker keeps the
/// cache counters deterministic; two exec workers keep crashes and
/// steals racing for real.
fn serve(
    queue: ExecQueueKind,
    fault: Option<Arc<FaultPlan>>,
) -> (Vec<JobResult>, CoordinatorMetrics) {
    let spec = WorkloadSpec::ttst();
    let coord = Coordinator::with_config(
        SystemConfig::for_workload(&spec),
        CoordinatorConfig {
            plan_workers: 1,
            exec_workers: 2,
            exec_queue: queue,
            fault,
            ..Default::default()
        },
    );
    for j in corpus(&spec, CORPUS_JOBS - 1) {
        coord.submit(j).unwrap();
    }
    let (mut results, metrics) = coord.drain();
    results.sort_by_key(|r| r.id);
    (results, metrics)
}

#[test]
fn seeded_kills_within_budget_leave_both_queues_bitwise_identical() {
    for queue in [ExecQueueKind::WorkStealing, ExecQueueKind::SingleQueue] {
        let (base_results, base_metrics) = serve(queue, None);
        assert_eq!(base_metrics.worker_deaths, 0);

        // Two kills ≤ the default per-job budget (2): even if both land
        // on the same unit, no job can be abandoned.
        let fault = Arc::new(FaultPlan::at_global_units(&[2, 5]));
        let (results, metrics) = serve(queue, Some(Arc::clone(&fault)));

        assert_eq!(fault.fired(), 2, "{queue:?}: both planned kills fire");
        // Exactly-once resolution: every id, once, no extras.
        let ids: Vec<usize> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..CORPUS_JOBS).collect::<Vec<_>>(), "{queue:?}");
        assert!(
            results.iter().all(|r| r.is_ok()),
            "{queue:?}: kills within budget must not fail jobs: {:?}",
            results.iter().find(|r| !r.is_ok()).map(|r| &r.error)
        );
        assert_eq!(metrics.jobs_submitted, CORPUS_JOBS);
        assert_eq!(metrics.jobs_done, CORPUS_JOBS);
        assert_eq!(metrics.jobs_failed, 0);
        assert_eq!(metrics.worker_deaths, 2, "{queue:?}");
        assert_eq!(metrics.units_requeued, 2, "{queue:?}");
        assert_eq!(metrics.units_abandoned, 0, "{queue:?}");

        // Bitwise identity against the undisturbed same-seed run.
        assert_eq!(
            digests(&base_results),
            digests(&results),
            "{queue:?}: retried execution diverged from the clean run"
        );

        // Unit conservation (work-stealing pops are observable): every
        // initial unit plus every crash requeue was popped exactly once.
        if queue == ExecQueueKind::WorkStealing {
            let pops = metrics.exec_local_pops
                + metrics.exec_injector_pops
                + metrics.exec_steal_successes;
            assert_eq!(
                pops,
                CORPUS_UNITS + metrics.units_requeued,
                "pool pops must conserve units incl. requeues"
            );
        }
    }
}

#[test]
fn a_two_node_fleet_survives_seeded_kills_bitwise() {
    let spec = WorkloadSpec::ttst();
    let run = |fault: Option<Arc<FaultPlan>>| {
        let cluster = Cluster::new(
            SystemConfig::for_workload(&spec),
            ClusterConfig {
                nodes: 2,
                route: RoutePolicy::FingerprintAffinity,
                admit_cap: None,
                node: CoordinatorConfig {
                    plan_workers: 1,
                    exec_workers: 1,
                    fault,
                    ..Default::default()
                },
            },
        );
        for j in corpus(&spec, CORPUS_JOBS - 1) {
            cluster.submit(j).unwrap();
        }
        let (node_results, metrics) = cluster.drain();
        let mut results: Vec<JobResult> =
            node_results.into_iter().map(|nr| nr.result).collect();
        results.sort_by_key(|r| r.id);
        (results, metrics)
    };

    let (base_results, _) = run(None);
    // The fault plan Arc is shared by both nodes (ClusterConfig.node is
    // cloned per node), so kill ordinals count fleetwide and each fires
    // at most once across the fleet.
    let fault = Arc::new(FaultPlan::at_global_units(&[1, 3]));
    let (results, metrics) = run(Some(Arc::clone(&fault)));

    assert_eq!(fault.fired(), 2);
    let ids: Vec<usize> = results.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..CORPUS_JOBS).collect::<Vec<_>>());
    assert!(results.iter().all(|r| r.is_ok()));
    // Fleet accounting stays exact under injected crashes.
    assert_eq!(metrics.submitted, CORPUS_JOBS);
    assert_eq!(metrics.completed + metrics.shed, metrics.submitted);
    assert_eq!(metrics.shed, 0);
    assert_eq!(metrics.worker_deaths, 2);
    assert_eq!(metrics.units_requeued, 2);
    assert_eq!(metrics.units_abandoned, 0);
    // Affinity routing + per-node plan determinism: the disturbed fleet
    // reproduces the clean fleet bitwise.
    assert_eq!(digests(&base_results), digests(&results));
}

#[test]
fn budget_exhaustion_fails_one_job_explicitly_and_serves_the_rest() {
    let spec = WorkloadSpec::ttst();
    // Three kills against a budget of 2 on a 1-unit job: submitted one
    // at a time, the first job's unit absorbs ordinals 1–3 and is
    // abandoned; later jobs run clean. The coordinator never hangs and
    // never drops a result.
    let fault = Arc::new(FaultPlan::at_global_units(&[1, 2, 3]));
    let coord = Coordinator::with_config(
        SystemConfig::for_workload(&spec),
        CoordinatorConfig {
            plan_workers: 1,
            exec_workers: 1,
            exec_queue: ExecQueueKind::WorkStealing,
            fault: Some(Arc::clone(&fault)),
            ..Default::default()
        },
    );
    let traces = gen_traces(&spec, 3, 9);
    let mut results = Vec::new();
    let mut stream = coord.results();
    for (id, t) in traces.into_iter().enumerate() {
        coord.submit(Job::new(id, t, spec.sf)).unwrap();
        results.push(stream.next().expect("every job resolves"));
    }
    drop(stream);
    let (rest, metrics) = coord.drain();
    assert!(rest.is_empty());
    assert_eq!(results.len(), 3);
    let err = results[0].error.as_deref().expect("exhaustion surfaces");
    assert!(err.contains("retry budget"), "got: {err}");
    assert!(results[1..].iter().all(|r| r.is_ok()));
    assert_eq!(fault.fired(), 3);
    assert_eq!(metrics.worker_deaths, 3);
    assert_eq!(metrics.units_requeued, 2);
    assert_eq!(metrics.units_abandoned, 1);
    assert_eq!(metrics.jobs_submitted, 3);
    assert_eq!(metrics.jobs_done + metrics.jobs_failed, 3);
    assert_eq!(metrics.jobs_failed, 1);
}
