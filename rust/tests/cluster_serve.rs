//! Fleet-level properties of the Layer-4 cluster: routing determinism
//! across rebuilds, decode-session stickiness, balance of the affinity
//! router over a Table-I corpus, exact shed accounting under overload,
//! and the 1-node degenerate case matching a plain coordinator.

use sata::cluster::{
    route_affinity, Admission, Cluster, ClusterConfig, RoutePolicy,
};
use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{Coordinator, CoordinatorConfig, Job, Request};
use sata::model::ModelTrace;
use sata::prop_assert;
use sata::trace::synth::{gen_sessions, gen_traces};
use sata::util::prop::check;

fn ttst() -> (WorkloadSpec, SystemConfig) {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    (spec, sys)
}

/// Deterministic node shape for tests that compare exact counts.
fn one_pipe() -> CoordinatorConfig {
    CoordinatorConfig { plan_workers: 1, exec_workers: 1, ..Default::default() }
}

#[test]
fn affinity_routing_is_deterministic_across_cluster_rebuilds() {
    let (spec, sys) = ttst();
    let corpus: Vec<Request> = gen_traces(&spec, 12, 0xD1CE)
        .into_iter()
        .map(Request::from)
        .chain(
            gen_sessions(&spec, 4, 2, 0.5, 3, 0.5, 0xD1CE).into_iter().map(Request::from),
        )
        .collect();

    // Two independently built clusters — different node configs, same
    // shape — must agree on every home node, and agree with the pure
    // routing function. Property-checked over random corpus picks.
    let a = Cluster::new(sys.clone(), ClusterConfig { nodes: 3, ..Default::default() });
    let b = Cluster::new(
        sys,
        ClusterConfig { nodes: 3, node: one_pipe(), ..Default::default() },
    );
    check("home node survives cluster rebuilds", 100, |rng| {
        let r = &corpus[rng.gen_range(corpus.len())];
        let home = route_affinity(r.fingerprint(), 3);
        prop_assert!(
            a.home_node(r) == Some(home),
            "cluster A disagrees with pure route for fp {:#x}",
            r.fingerprint()
        );
        prop_assert!(
            b.home_node(r) == Some(home),
            "cluster B (different node config) disagrees for fp {:#x}",
            r.fingerprint()
        );
        Ok(())
    });
    a.finish();
    b.finish();
}

#[test]
fn decode_session_steps_stay_on_one_node() {
    let (spec, sys) = ttst();
    let session = gen_sessions(&spec, 1, 2, 0.5, 4, 0.5, 0x5E55).remove(0);
    let cluster = Cluster::new(sys, ClusterConfig { nodes: 3, ..Default::default() });
    let home = cluster
        .home_node(&Request::from(session.clone()))
        .expect("affinity routes by content");

    // Resubmitting the same session (a later turn of the same dialogue)
    // must land on the same node every time — stickiness is structural.
    for id in 0..3 {
        match cluster.submit(Job::new(id, session.clone(), spec.sf)).unwrap() {
            Admission::Accepted { node } => assert_eq!(node, home),
            Admission::Shed { .. } => panic!("no cap configured"),
        }
    }
    let (results, m) = cluster.drain();
    assert_eq!(results.len(), 3);
    for r in &results {
        assert_eq!(r.node, home, "job {} served off the home node", r.result.id);
        assert_eq!(r.result.tokens, 4);
    }
    // Every generated token was served by the home node; the other two
    // coordinators never saw a decode step.
    for (i, node) in m.nodes.iter().enumerate() {
        let expect = if i == home { 3 * 4 } else { 0 };
        assert_eq!(node.tokens_done, expect, "node {i} token count");
    }
}

#[test]
fn affinity_routing_balances_the_table1_corpus() {
    // Rendezvous hashing over mix64 scores should spread a real corpus
    // roughly evenly: max/min per-node key count within a factor of 2.
    // (Binomial bounds: 256 keys over 2 nodes and 512 over 4 keep the
    // ratio comfortably inside 2x at >3 sigma.)
    let spec = WorkloadSpec::ttst();
    for (nodes, n_keys, seed) in [(2usize, 256usize, 0xBA1A), (4, 512, 0xBA1B)] {
        let mut counts = vec![0usize; nodes];
        for t in gen_traces(&spec, n_keys, seed) {
            let fp = ModelTrace::from(t).fingerprint();
            counts[route_affinity(fp, nodes)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 0, "{nodes} nodes: a node got no keys: {counts:?}");
        assert!(
            max < 2 * min,
            "{nodes} nodes: imbalance {counts:?} (max {max} >= 2 x min {min})"
        );
    }
}

#[test]
fn shed_accounting_is_exact_under_overload() {
    let (spec, sys) = ttst();
    // Tiny per-node cap + an unpaced burst of 40 jobs = far past 2x
    // overload: most of the burst must shed, and every single submission
    // must be accounted — submitted == completed + shed, exactly.
    let cluster = Cluster::new(
        sys,
        ClusterConfig {
            nodes: 2,
            admit_cap: Some(2),
            node: one_pipe(),
            ..Default::default()
        },
    );
    let n = 40;
    let (mut accepted, mut shed) = (0usize, 0usize);
    for (id, t) in gen_traces(&spec, n, 0x0BAD).into_iter().enumerate() {
        match cluster.submit(Job::new(id, t, spec.sf)).unwrap() {
            Admission::Accepted { .. } => accepted += 1,
            Admission::Shed { .. } => shed += 1,
        }
    }
    let (results, m) = cluster.drain();
    assert!(shed > 0, "a 2-per-node cap must shed under a 40-job burst");
    assert_eq!(m.submitted, n, "every submission counted");
    assert_eq!(m.completed, accepted, "every accepted job delivered a result");
    assert_eq!(m.shed, shed, "every shed counted");
    assert_eq!(
        m.submitted,
        m.completed + m.shed,
        "the accounting identity must hold exactly — no silent losses"
    );
    assert_eq!(results.len(), accepted);
    assert_eq!(m.shed_per_node.iter().sum::<usize>(), m.shed);
}

#[test]
fn one_node_affinity_cluster_matches_a_plain_coordinator() {
    let (spec, sys) = ttst();
    let requests: Vec<Request> =
        gen_traces(&spec, 8, 0x1807).into_iter().map(Request::from).collect();

    let coord = Coordinator::with_config(sys.clone(), one_pipe());
    for (id, r) in requests.iter().cloned().enumerate() {
        coord.submit(Job::new(id, r, spec.sf)).unwrap();
    }
    let (plain, pm) = coord.drain();

    let cluster = Cluster::new(
        sys,
        ClusterConfig { nodes: 1, node: one_pipe(), ..Default::default() },
    );
    for (id, r) in requests.iter().cloned().enumerate() {
        cluster.submit(Job::new(id, r, spec.sf)).unwrap();
    }
    let (fleet, fm) = cluster.drain();

    assert_eq!(plain.len(), fleet.len());
    for (a, b) in plain.iter().zip(&fleet) {
        assert_eq!(b.node, 0);
        assert_eq!(a.id, b.result.id);
        assert_eq!(a.dense, b.result.dense, "job {}: report diverged", a.id);
        assert_eq!(a.cache_hits, b.result.cache_hits);
    }
    assert_eq!(pm.cache_hits, fm.cache_hits);
    assert_eq!(pm.cache_misses, fm.cache_misses);
    assert_eq!(fm.submitted, fm.completed + fm.shed);
}
