//! Decode-session acceptance tests: a 0-step session through the
//! coordinator is **bitwise identical** to the model-request path for
//! all seven flows on both substrates; `gen_session`'s `kappa` knob
//! produces valid sessions with monotone step overlap; step-carryover
//! residency never claims a key the previous step did not fetch; and the
//! pipelined coordinator path agrees exactly with the single-threaded
//! `decode::run_session` reference.

use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{Coordinator, CoordinatorConfig, Job, Request};
use sata::decode::{carry_residency, run_session, DecodeSession};
use sata::engine::{backend, substrate, EngineOpts};
use sata::trace::synth::{gen_model, gen_session, gen_trace};
use sata::trace::TraceDir;
use sata::util::prop::check;

#[test]
fn zero_step_session_is_bitwise_identical_to_the_model_path_everywhere() {
    // The decode refactor's golden contract: for every Table-I workload,
    // every registered flow, and both substrates, a 0-step DecodeSession
    // served through the coordinator reproduces the model-request path's
    // reports bit for bit — dense baseline, per-flow totals, and
    // per-layer entries.
    for spec in WorkloadSpec::all_paper() {
        let flow_names: Vec<String> =
            backend::flow_names().iter().map(|s| s.to_string()).collect();
        let trace = gen_trace(&spec, 23);
        for sspec in &substrate::SUBSTRATES {
            let sys = SystemConfig::for_workload(&spec);
            let coord = Coordinator::new(2, 4, sys);
            coord
                .submit(
                    Job::with_flows(0, trace.clone(), spec.sf, flow_names.clone())
                        .on_substrate(sspec.name),
                )
                .unwrap();
            coord
                .submit(
                    Job::with_flows(
                        1,
                        DecodeSession::from(trace.clone()),
                        spec.sf,
                        flow_names.clone(),
                    )
                    .on_substrate(sspec.name),
                )
                .unwrap();
            let (results, _) = coord.drain();
            assert_eq!(results.len(), 2);
            let (model, decode) = (&results[0], &results[1]);
            assert!(model.is_ok() && decode.is_ok(), "{:?}", decode.error);
            assert_eq!(decode.tokens, 0);
            assert_eq!(decode.layers, model.layers);
            let tag = format!("{}@{}", spec.name, sspec.name);
            assert_eq!(decode.dense, model.dense, "{tag}: dense diverged");
            assert_eq!(decode.flows.len(), model.flows.len());
            for (d, m) in decode.flows.iter().zip(&model.flows) {
                assert_eq!(d.flow, m.flow);
                assert_eq!(d.report, m.report, "{tag} {}: report diverged", d.flow);
                assert_eq!(d.throughput_gain, m.throughput_gain, "{tag} {}", d.flow);
                assert_eq!(d.energy_gain, m.energy_gain, "{tag} {}", d.flow);
            }
        }
    }
}

#[test]
fn coordinator_decode_path_matches_the_run_session_reference() {
    // The pipelined, unit-interleaved coordinator path and the
    // single-threaded decode::run_session reference must agree exactly —
    // no hidden cross-unit state, no ordering sensitivity.
    let spec = WorkloadSpec::ttst();
    let session = gen_session(&spec, 2, 0.5, 5, 0.6, 31);
    let sys = SystemConfig::for_workload(&spec);
    let opts = EngineOpts {
        sf: spec.sf,
        theta_frac: sys.theta_frac,
        seed: sys.seed,
        ..Default::default()
    };
    for sspec in &substrate::SUBSTRATES {
        for carry in [true, false] {
            let sub = (sspec.build)(&sys, spec.dk);
            let expected =
                run_session(&backend::SATA, &session, &*sub, opts, carry);

            let coord = Coordinator::new(2, 4, SystemConfig::for_workload(&spec));
            coord
                .submit(
                    Job::new(0, session.clone(), spec.sf)
                        .on_substrate(sspec.name)
                        .with_carryover(carry),
                )
                .unwrap();
            let (results, metrics) = coord.drain();
            let r = &results[0];
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(r.layers, 2);
            assert_eq!(r.tokens, 5);
            assert_eq!(
                r.flows[0].report, expected,
                "{} carry={carry} diverged from reference",
                sspec.name
            );
            assert_eq!(metrics.tokens_done, 5);
        }
    }
}

#[test]
fn gen_session_is_valid_and_servable_for_all_kappa() {
    check("gen_session valid + servable over kappa", 6, |rng| {
        let spec = WorkloadSpec::ttst();
        let kappa = rng.f64();
        let steps = 1 + rng.gen_range(5);
        let s = gen_session(&spec, 1 + rng.gen_range(2), rng.f64(), steps, kappa, rng.next_u64());
        s.validate().map_err(|e| format!("kappa {kappa:.2}: {e}"))?;
        // JSON-reloadable with identical identity.
        let back = DecodeSession::from_json(&s.to_json())
            .map_err(|e| format!("reload failed: {e}"))?;
        if back.fingerprint() != s.fingerprint() {
            return Err("fingerprint changed across JSON roundtrip".into());
        }
        // Servable end to end.
        let coord = Coordinator::new(1, 2, SystemConfig::for_workload(&spec));
        coord
            .submit(Job::new(0, s, spec.sf))
            .map_err(|_| "submit failed".to_string())?;
        let (results, _) = coord.drain();
        if !results[0].is_ok() {
            return Err(format!("serve failed: {:?}", results[0].error));
        }
        if results[0].tokens != steps {
            return Err("token count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn step_overlap_is_monotone_in_kappa() {
    let spec = WorkloadSpec::drsformer();
    let grid = [0.0, 0.25, 0.5, 0.75, 1.0];
    for seed in [5u64, 19] {
        let overlaps: Vec<f64> = grid
            .iter()
            .map(|&kappa| gen_session(&spec, 1, 0.0, 6, kappa, seed).step_overlap())
            .collect();
        for w in overlaps.windows(2) {
            assert!(w[1] >= w[0] - 0.03, "not monotone (seed {seed}): {overlaps:?}");
        }
        assert!(
            overlaps[4] > overlaps[0] + 0.15,
            "no dynamic range (seed {seed}): {overlaps:?}"
        );
        assert!((overlaps[4] - 1.0).abs() < 1e-12);
    }
}

#[test]
fn carryover_residency_never_claims_an_unfetched_key() {
    // The residency contract, property-tested over random kappa/depths:
    // every key charged resident at step t was selected (hence fetched)
    // by step t−1 AND is selected by step t; step 0 carries nothing.
    check("carry residency ⊆ previous fetch set", 12, |rng| {
        let spec = WorkloadSpec::ttst();
        let steps = 1 + rng.gen_range(6);
        let s = gen_session(&spec, 1, 0.0, steps, rng.f64(), rng.next_u64());
        let res = carry_residency(&s);
        if res.len() != steps {
            return Err("residency length mismatch".into());
        }
        if !res[0].iter().all(|h| h.is_empty()) {
            return Err("step 0 must carry nothing".into());
        }
        for t in 1..steps {
            for (h, keys) in res[t].iter().enumerate() {
                for k in keys {
                    if !s.steps[t - 1].heads[h].contains(k) {
                        return Err(format!(
                            "step {t} head {h}: key {k} claimed resident but not fetched at step {}",
                            t - 1
                        ));
                    }
                    if !s.steps[t].heads[h].contains(k) {
                        return Err(format!(
                            "step {t} head {h}: resident key {k} not selected this step"
                        ));
                    }
                }
                // And the set is exactly the intersection: nothing
                // selected-by-both is left unclaimed (the reuse metric
                // must not undercount either).
                let missed = s.steps[t].heads[h]
                    .iter()
                    .filter(|k| s.steps[t - 1].heads[h].contains(k))
                    .count();
                if missed != keys.len() {
                    return Err(format!(
                        "step {t} head {h}: residency {} != intersection {missed}",
                        keys.len()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn request_load_dispatches_on_file_shape() {
    // serve --traces-dir's per-file loader: one read + one JSON parse,
    // dispatched on shape — bare trace, model file, session file; hostile
    // files yield per-file errors.
    let dir = std::env::temp_dir().join("sata_request_load_test");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let spec = WorkloadSpec::ttst();
    gen_trace(&spec, 1).save(&dir.join("a_single.json")).unwrap();
    gen_model(&spec, 2, 0.5, 2).save(&dir.join("b_model.json")).unwrap();
    gen_session(&spec, 1, 0.0, 3, 0.5, 3).save(&dir.join("c_session.json")).unwrap();
    std::fs::write(dir.join("d_bad.json"), "{ nope").unwrap();

    let paths = TraceDir::open(&dir).unwrap().into_paths();
    assert_eq!(paths.len(), 4, "sorted path listing");
    match Request::load(&paths[0]).unwrap() {
        Request::Model(m) => assert_eq!(m.n_layers(), 1),
        other => panic!("bare trace loaded as {other:?}"),
    }
    match Request::load(&paths[1]).unwrap() {
        Request::Model(m) => assert_eq!(m.n_layers(), 2),
        other => panic!("model file loaded as {other:?}"),
    }
    match Request::load(&paths[2]).unwrap() {
        Request::Decode(s) => assert_eq!(s.n_steps(), 3),
        other => panic!("session file loaded as {other:?}"),
    }
    assert!(Request::load(&paths[3]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_corpus_serves_models_and_sessions_together() {
    // serve's corpus shape: a directory-style mix of prefill requests and
    // decode sessions interleaving through one coordinator, with decode
    // metrics folding only the session jobs.
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    let coord = Coordinator::with_config(
        sys,
        CoordinatorConfig { plan_workers: 2, exec_workers: 2, ..Default::default() },
    );
    coord.submit(Job::new(0, gen_trace(&spec, 1), spec.sf)).unwrap();
    coord
        .submit(Job::new(1, gen_session(&spec, 1, 0.0, 4, 0.7, 2), spec.sf))
        .unwrap();
    coord.submit(Job::new(2, gen_trace(&spec, 3), spec.sf)).unwrap();
    let (results, metrics) = coord.drain();
    assert_eq!(results.len(), 3);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(results[0].tokens, 0);
    assert_eq!(results[1].tokens, 4);
    assert_eq!(results[2].tokens, 0);
    assert_eq!(metrics.tokens_done, 4);
    assert_eq!(metrics.layers_planned, 3);
    assert_eq!(metrics.live_sessions_peak, 1);
    assert!(metrics.carry_fetched_keys > 0);
    assert!(metrics.token_p50_ns > 0.0);
}
