//! Delta-planning invariants: `StepPlan::patch_from` must be **bitwise
//! identical** to cold Algo-1 planning (`StepPlan::build`) along any
//! decode chain, and the coordinator must therefore serve exactly the
//! same results with delta planning on or off — for every registered
//! flow and every step-overlap kappa. The only observable difference is
//! the `steps_planned_cold` / `steps_planned_delta` split in the
//! metrics, which is pinned exactly here.

use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{
    Coordinator, CoordinatorConfig, CoordinatorMetrics, Job, JobResult,
};
use sata::engine::backend::{flow_names, StepPlan};
use sata::engine::EngineOpts;
use sata::trace::synth::gen_sessions;
use sata::util::rng::{mix64, Rng};

const KAPPAS: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];
const STEPS: usize = 6;

/// Plan `chain` twice — cold every step, and delta-patched from the
/// (itself patched) predecessor — and require bitwise-equal plans.
fn assert_chain_matches(chain: &[(Vec<Vec<usize>>, u64)], opts: EngineOpts) {
    let mut scratch: Vec<bool> = Vec::new();
    let mut prev: Option<StepPlan> = None;
    for (t, (heads, fp)) in chain.iter().enumerate() {
        let cold = StepPlan::build(heads, *fp, opts);
        let plan = match &prev {
            Some(p) => StepPlan::patch_from(p, heads, *fp, opts, &mut scratch),
            None => StepPlan::build(heads, *fp, opts),
        };
        assert_eq!(
            plan.heads, cold.heads,
            "step {t}: patched selection order diverges from cold Algo-1 plan"
        );
        assert_eq!(plan.fingerprint, cold.fingerprint, "step {t}: cache identity diverges");
        assert_eq!(plan.opts.cache_key(), cold.opts.cache_key(), "step {t}: opts diverge");
        prev = Some(plan);
    }
}

#[test]
fn patched_plans_are_bitwise_identical_to_cold() {
    for &kappa in &KAPPAS {
        for (spec, seed) in
            [(WorkloadSpec::ttst(), 11u64), (WorkloadSpec::kvt_deit_tiny(), 23)]
        {
            let sessions = gen_sessions(&spec, 2, 1, 0.0, 8, kappa, seed);
            for opts in [EngineOpts::default(), EngineOpts { seed: 7, ..Default::default() }]
            {
                for sess in &sessions {
                    let chain: Vec<(Vec<Vec<usize>>, u64)> = sess
                        .steps
                        .iter()
                        .map(|s| (s.heads.clone(), s.fingerprint()))
                        .collect();
                    assert_chain_matches(&chain, opts);
                }
            }
        }
    }
}

/// `gen_sessions` transitions are either verbatim copies (Δ = ∅) or fresh
/// draws; this chain exercises the in-between — per-key overlap of
/// exactly `round(kappa·K)` retained keys per transition, over a KV
/// window that grows step to step (new keys can exceed every old index).
#[test]
fn patching_handles_partial_overlap_and_kv_growth() {
    let opts = EngineOpts::default();
    let (heads_n, k) = (4usize, 24usize);
    for &kappa in &KAPPAS {
        let mut rng = Rng::new(0xD17A ^ kappa.to_bits());
        let keep = (kappa * k as f64).round() as usize;
        let mut chain: Vec<(Vec<Vec<usize>>, u64)> = Vec::new();
        for t in 0..10usize {
            let kv = 64 + 8 * t;
            let heads: Vec<Vec<usize>> = (0..heads_n)
                .map(|h| {
                    let mut keys: Vec<usize> = match chain.last() {
                        None => rng.sample_indices(kv, k),
                        Some((prev, _)) => {
                            let mut keys: Vec<usize> = prev[h][..keep].to_vec();
                            while keys.len() < k {
                                let cand = rng.gen_range(kv);
                                if !keys.contains(&cand) {
                                    keys.push(cand);
                                }
                            }
                            keys
                        }
                    };
                    rng.shuffle(&mut keys);
                    keys
                })
                .collect();
            let fp = mix64(0xC4A1_0000 ^ ((t as u64) << 8) ^ kappa.to_bits());
            chain.push((heads, fp));
        }
        assert_chain_matches(&chain, opts);
    }
}

/// Canonical job blob with the nondeterministic wall-latency field
/// excluded — everything else must be bitwise equal across delta on/off.
fn canon(r: &JobResult) -> String {
    let mut s = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{:?}|{}",
        r.id,
        r.model,
        r.substrate,
        r.layers,
        r.tokens,
        r.cache_hits,
        r.carry_resident,
        r.carry_fetched,
        r.error,
        r.dense.to_json().emit(),
    );
    for f in &r.flows {
        s.push_str(&format!(
            "|{}|{}|{}|{}",
            f.flow,
            f.throughput_gain,
            f.energy_gain,
            f.report.to_json().emit()
        ));
    }
    s
}

fn serve(kappa: f64, delta: bool) -> (Vec<String>, CoordinatorMetrics) {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    let coord = Coordinator::with_config(
        sys,
        // Capacity above the working set: the hit/delta/cold split below
        // is exact, not eviction luck.
        CoordinatorConfig { cache_capacity: 1024, ..Default::default() },
    );
    // 1-layer prefills with distinct per-session seeds: every step-plan
    // cache hit is a genuine within-session copy transition.
    let sessions = gen_sessions(&spec, 3, 1, 0.0, STEPS, kappa, 0xFACE);
    let n = sessions.len();
    let mut blobs = Vec::new();
    std::thread::scope(|s| {
        s.spawn(|| {
            for (id, sess) in sessions.into_iter().enumerate() {
                let flows = flow_names().iter().map(|f| f.to_string()).collect();
                let job = Job::with_flows(id, sess, spec.sf, flows).with_delta(delta);
                coord.submit(job).expect("submit");
            }
        });
        for r in coord.results().take(n) {
            assert!(r.is_ok(), "{:?}", r.error);
            blobs.push(canon(&r));
        }
    });
    blobs.sort();
    (blobs, coord.finish())
}

#[test]
fn delta_on_off_serve_identically_across_flows_and_kappa() {
    let sessions = 3;
    for &kappa in &KAPPAS {
        let copies = (kappa * (STEPS - 1) as f64).round() as usize;
        let (on_blobs, on_m) = serve(kappa, true);
        let (off_blobs, off_m) = serve(kappa, false);
        assert_eq!(
            on_blobs, off_blobs,
            "kappa {kappa}: delta-on and delta-off served different results"
        );

        // Exact per-step planning outcome accounting: with delta on, only
        // each session's first step plans cold; every non-copy successor
        // is patched, every copy transition hits the cache. With delta
        // off every miss plans cold. The hit count must not move at all.
        assert_eq!(on_m.steps_cache_hit, sessions * copies, "kappa {kappa}");
        assert_eq!(off_m.steps_cache_hit, sessions * copies, "kappa {kappa}");
        assert_eq!(on_m.steps_planned_cold, sessions, "kappa {kappa}");
        assert_eq!(
            on_m.steps_planned_delta,
            sessions * (STEPS - 1 - copies),
            "kappa {kappa}"
        );
        assert_eq!(off_m.steps_planned_delta, 0, "kappa {kappa}");
        assert_eq!(
            off_m.steps_planned_cold,
            sessions * (STEPS - copies),
            "kappa {kappa}"
        );

        // The stage split sees every job and unit.
        assert!(on_m.plan_total_ns > 0.0, "plan stage recorded nothing");
        assert!(on_m.exec_total_ns > 0.0, "exec stage recorded nothing");
        assert!(on_m.plan_p50_ns > 0.0 && on_m.exec_p50_ns > 0.0);
    }
}
