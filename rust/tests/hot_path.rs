//! Hot-path concurrency stress: the work-stealing execution pool and the
//! RwLock-sharded plan cache must be *pure scheduling changes* — same
//! results, exact accounting — under contention, across seeds.
//!
//! Tier-1: these run in the default `cargo test` sweep.

use sata::cluster::{Admission, Cluster, ClusterConfig, RoutePolicy};
use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{
    Coordinator, CoordinatorConfig, ExecQueueKind, Job, JobResult, Request,
};
use sata::trace::synth::{gen_sessions, gen_traces, ArrivalGen, ArrivalSpec};

/// Mixed prefill + decode stream (repeat traffic, so the plan cache and
/// the exec queue both stay busy).
fn stream(spec: &WorkloadSpec, seed: u64, n: usize) -> Vec<Request> {
    ArrivalGen::new(
        spec,
        ArrivalSpec {
            rate_per_s: 0.0,
            decode_frac: 0.5,
            distinct: 3,
            layers: 2,
            rho: 0.5,
            steps: 3,
            kappa: 0.7,
        },
        seed,
    )
    .take(n)
    .map(|a| a.request)
    .collect()
}

fn serve(
    sys: &SystemConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    cfg: CoordinatorConfig,
) -> (Vec<JobResult>, sata::coordinator::CoordinatorMetrics) {
    let coord = Coordinator::with_config(sys.clone(), cfg);
    for (id, r) in requests.iter().cloned().enumerate() {
        coord.submit(Job::new(id, r, spec.sf)).expect("open coordinator");
    }
    coord.drain()
}

fn assert_bitwise_equal(a: &[JobResult], b: &[JobResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.model, y.model);
        assert_eq!(x.layers, y.layers);
        assert_eq!(x.tokens, y.tokens);
        assert!(x.error.is_none() && y.error.is_none());
        assert_eq!(x.dense, y.dense, "job {}: dense baseline diverged", x.id);
        assert_eq!(x.flows.len(), y.flows.len());
        for (fx, fy) in x.flows.iter().zip(&y.flows) {
            assert_eq!(fx.flow, fy.flow);
            assert_eq!(fx.report, fy.report, "job {}: flow report diverged", x.id);
            assert_eq!(fx.throughput_gain.to_bits(), fy.throughput_gain.to_bits());
            assert_eq!(fx.energy_gain.to_bits(), fy.energy_gain.to_bits());
        }
        assert_eq!(x.cache_hits, y.cache_hits, "job {}: cache accounting diverged", x.id);
        assert_eq!(x.cache_hit, y.cache_hit);
        assert_eq!(x.carry_resident, y.carry_resident);
        assert_eq!(x.carry_fetched, y.carry_fetched);
    }
}

/// Work stealing is observationally identical to the single queue: same
/// stream, one plan worker (deterministic cache order), four contending
/// exec workers — bitwise-equal results and accounting, several seeds.
#[test]
fn work_stealing_matches_single_queue_bitwise_across_seeds() {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    for seed in [1u64, 42, 0xBEEF] {
        let requests = stream(&spec, seed, 12);
        let cfg = |kind| CoordinatorConfig {
            plan_workers: 1,
            exec_workers: 4,
            cache_capacity: 256,
            exec_queue: kind,
            ..Default::default()
        };
        let (ws, ws_m) = serve(&sys, &spec, &requests, cfg(ExecQueueKind::WorkStealing));
        let (sq, sq_m) = serve(&sys, &spec, &requests, cfg(ExecQueueKind::SingleQueue));
        assert_bitwise_equal(&ws, &sq);
        assert_eq!(ws_m.cache_hits, sq_m.cache_hits, "seed {seed}");
        assert_eq!(ws_m.cache_misses, sq_m.cache_misses, "seed {seed}");
        assert_eq!(ws_m.cache_evictions, sq_m.cache_evictions, "seed {seed}");
        assert_eq!(ws_m.steps_cache_hit, sq_m.steps_cache_hit, "seed {seed}");
    }
}

/// Every planned unit is popped exactly once — local, injector-batch, or
/// steal — even with a tiny queue bound forcing backpressure, across
/// seeds. `units == jobs + decode steps` for this job mix.
#[test]
fn pool_counters_conserve_units_under_backpressure() {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    for seed in [7u64, 99, 0xD00D] {
        let traces: Vec<Request> =
            gen_traces(&spec, 6, seed).into_iter().map(Request::from).collect();
        let sessions: Vec<Request> = gen_sessions(&spec, 2, 1, 0.0, 3, 0.7, seed)
            .into_iter()
            .map(Request::Decode)
            .collect();
        let requests: Vec<Request> =
            traces.into_iter().chain(sessions).collect();
        // 6 single-layer prefills (1 unit each) + 2 sessions of 1 layer +
        // 3 steps (4 units each) = 14 planned units.
        let expected_units = 6 + 2 * (1 + 3);

        let (results, m) = serve(
            &sys,
            &spec,
            &requests,
            CoordinatorConfig {
                plan_workers: 2,
                exec_workers: 3,
                queue_cap: 2, // force producer backpressure + injector churn
                exec_queue: ExecQueueKind::WorkStealing,
                ..Default::default()
            },
        );
        assert_eq!(results.len(), 8, "seed {seed}");
        assert_eq!(m.jobs_done, 8, "seed {seed}");
        assert_eq!(m.jobs_failed, 0, "seed {seed}");
        assert_eq!(
            m.exec_local_pops + m.exec_injector_pops + m.exec_steal_successes,
            expected_units,
            "seed {seed}: a unit was dropped or double-executed"
        );
        // Counter sanity: attempts bound successes, each success moved
        // at least one unit, and the ratio is a valid fraction.
        assert!(m.exec_steal_attempts >= m.exec_steal_successes, "seed {seed}");
        assert!(m.exec_stolen_units >= m.exec_steal_successes, "seed {seed}");
        assert!(
            (0.0..=1.0).contains(&m.queue_lockfree_ratio),
            "seed {seed}: ratio {}",
            m.queue_lockfree_ratio
        );
    }
}

/// A bursty over-admitted fleet of work-stealing nodes loses nothing:
/// `submitted == completed + shed`, exactly.
#[test]
fn cluster_burst_accounts_every_job_under_work_stealing() {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    let requests = stream(&spec, 0xFEED, 30);
    let cluster = Cluster::new(
        sys,
        ClusterConfig {
            nodes: 2,
            route: RoutePolicy::FingerprintAffinity,
            admit_cap: Some(2),
            node: CoordinatorConfig {
                plan_workers: 2,
                exec_workers: 2,
                exec_queue: ExecQueueKind::WorkStealing,
                ..Default::default()
            },
        },
    );
    let mut accepted = 0usize;
    let mut shed = 0usize;
    for (id, r) in requests.into_iter().enumerate() {
        match cluster.submit(Job::new(id, r, spec.sf)).expect("open cluster") {
            Admission::Accepted { .. } => accepted += 1,
            Admission::Shed { .. } => shed += 1,
        }
    }
    let (results, m) = cluster.drain();
    assert_eq!(m.submitted, 30);
    assert_eq!(m.completed, accepted);
    assert_eq!(m.shed, shed);
    assert_eq!(
        m.submitted,
        m.completed + m.shed,
        "a job was lost silently under burst admission"
    );
    assert_eq!(results.len(), accepted);
}

/// The degenerate 1-node work-stealing cluster is bitwise identical to a
/// plain work-stealing coordinator fed the same stream.
#[test]
fn one_node_ws_cluster_matches_plain_ws_coordinator() {
    let spec = WorkloadSpec::ttst();
    let sys = SystemConfig::for_workload(&spec);
    let requests = stream(&spec, 0xA11, 10);
    let cfg = CoordinatorConfig {
        plan_workers: 1,
        exec_workers: 2,
        exec_queue: ExecQueueKind::WorkStealing,
        ..Default::default()
    };
    let (plain, _) = serve(&sys, &spec, &requests, cfg.clone());

    let cluster = Cluster::new(
        sys,
        ClusterConfig {
            nodes: 1,
            route: RoutePolicy::FingerprintAffinity,
            admit_cap: None,
            node: cfg,
        },
    );
    for (id, r) in requests.iter().cloned().enumerate() {
        match cluster.submit(Job::new(id, r, spec.sf)).expect("open cluster") {
            Admission::Accepted { node } => assert_eq!(node, 0),
            Admission::Shed { .. } => panic!("no admission cap configured"),
        }
    }
    let (fleet, _) = cluster.drain();
    let fleet_results: Vec<JobResult> =
        fleet.into_iter().map(|nr| nr.result).collect();
    assert_bitwise_equal(&plain, &fleet_results);
}
