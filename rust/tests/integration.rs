//! Cross-module integration tests: trace → Algo 1/2 → engine → metrics,
//! plus coordinator wiring and failure-injection on malformed inputs.
use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{Coordinator, Job};
use sata::engine::{gains, run_dense, run_gated, run_sata, EngineOpts};
use sata::hw::cim::CimConfig;
use sata::hw::sched_rtl::SchedRtl;
use sata::schedule::{schedule_sata, validate, HeadPlan};
use sata::trace::synth::{gen_trace, gen_traces};
use sata::trace::MaskTrace;
use sata::util::json::Json;
use sata::util::prop::check;

#[test]
fn full_pipeline_all_paper_workloads() {
    let rtl = SchedRtl::tsmc65();
    for spec in WorkloadSpec::all_paper() {
        let t = gen_trace(&spec, 3);
        let cim = CimConfig::default_65nm(spec.dk);
        let dense = run_dense(&t.heads, &cim);
        let gated = run_gated(&t.heads, &cim, EngineOpts::default());
        let sata = run_sata(&t.heads, &cim, &rtl, EngineOpts { sf: spec.sf, ..Default::default() });
        // SATA must beat dense on both axes; gated saves energy vs dense.
        let g = gains(&dense, &sata);
        assert!(g.throughput > 1.0, "{}: {:.2}", spec.name, g.throughput);
        assert!(g.energy_eff > 1.0, "{}: {:.2}", spec.name, g.energy_eff);
        assert!(gated.total_pj() < dense.total_pj(), "{}", spec.name);
    }
}

#[test]
fn schedule_correctness_on_generated_traces() {
    check("generated-trace schedule correctness", 10, |rng| {
        let spec = WorkloadSpec::drsformer();
        let t = gen_trace(&spec, rng.next_u64());
        let plans: Vec<HeadPlan> = t
            .heads
            .iter()
            .enumerate()
            .map(|(h, m)| HeadPlan::build(h, m.clone(), m.n() / 2, 1))
            .collect();
        let s = schedule_sata(&plans);
        validate(&plans, &s)
    });
}

#[test]
fn trace_roundtrip_preserves_engine_results() {
    let spec = WorkloadSpec::ttst();
    let t = gen_trace(&spec, 9);
    let dir = std::env::temp_dir().join("sata_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ttst.json");
    t.save(&path).unwrap();
    let loaded = MaskTrace::load(&path).unwrap();
    let cim = CimConfig::default_65nm(spec.dk);
    let a = run_dense(&t.heads, &cim);
    let b = run_dense(&loaded.heads, &cim);
    assert_eq!(a.latency_ns, b.latency_ns);
    assert_eq!(a.total_pj(), b.total_pj());
    std::fs::remove_file(path).ok();
}

#[test]
fn coordinator_end_to_end_with_mixed_workloads() {
    let sys = SystemConfig::default();
    let coord = Coordinator::new(2, 4, sys);
    let mut id = 0;
    for spec in [WorkloadSpec::ttst(), WorkloadSpec::drsformer()] {
        for t in gen_traces(&spec, 2, 3) {
            coord.submit(Job { id, trace: t, sf: spec.sf });
            id += 1;
        }
    }
    let (results, metrics) = coord.drain();
    assert_eq!(results.len(), 4);
    assert!(metrics.mean_throughput_gain > 1.0);
}

#[test]
fn malformed_trace_files_are_rejected_not_panicking() {
    for bad in [
        "",
        "{",
        r#"{"n": 0, "heads": []}"#,
        r#"{"n": 4, "heads": [[[9]]]}"#, // wrong row count
    ] {
        if let Ok(j) = Json::parse(bad) {
            assert!(MaskTrace::from_json(&j).is_err(), "accepted: {bad}");
        }
    }
}

#[test]
fn engine_is_deterministic_given_seed() {
    let spec = WorkloadSpec::kvt_deit_tiny();
    let t = gen_trace(&spec, 4);
    let cim = CimConfig::default_65nm(spec.dk);
    let rtl = SchedRtl::tsmc65();
    let opts = EngineOpts { sf: spec.sf, seed: 77, ..Default::default() };
    let a = run_sata(&t.heads, &cim, &rtl, opts);
    let b = run_sata(&t.heads, &cim, &rtl, opts);
    assert_eq!(a.latency_ns, b.latency_ns);
    assert_eq!(a.total_pj(), b.total_pj());
    assert_eq!(a.steps, b.steps);
}
