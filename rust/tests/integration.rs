//! Cross-module integration tests: trace → Algo 1/2 → engine → metrics,
//! plus coordinator wiring, the `FlowBackend` registry (golden equivalence
//! against the pre-refactor `run_*` implementations, residency across all
//! backends) and failure-injection on malformed inputs.
use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{Coordinator, Job, PlanCache};
use sata::engine::backend::{self, FlowBackend, PlanSet};
use sata::engine::{gains, run_dense, run_gated, run_sata, substrate, EngineOpts};
use sata::hw::cim::CimConfig;
use sata::hw::sched_rtl::SchedRtl;
use sata::mask::SelectiveMask;
use sata::schedule::{schedule_sata, validate, HeadPlan};
use sata::trace::synth::{gen_trace, gen_traces};
use sata::trace::MaskTrace;
use sata::util::json::Json;
use sata::util::prop::check;

/// Faithful copies of the pre-refactor free-function flows (the seed's
/// `run_dense`/`run_gated`/`run_sata`), built on the retained bit-by-bit
/// `chunked_k_uses_ref`. The `FlowBackend` ports must reproduce these
/// bitwise — the golden contract of the refactor.
mod legacy {
    use std::collections::HashMap;

    use sata::engine::{chunked_k_uses_ref, EngineOpts, RunReport};
    use sata::hw::cim::CimConfig;
    use sata::hw::sched_rtl::SchedRtl;
    use sata::hw::OpCosts;
    use sata::mask::SelectiveMask;
    use sata::schedule::tiled::schedule_tiled;
    use sata::schedule::{schedule_sata, schedule_sequential, HeadPlan, Schedule};

    fn accumulate(
        sched: &Schedule,
        c: &OpCosts,
        overlap: bool,
        fresh_k_frac: f64,
        k_factor: &HashMap<usize, f64>,
        rep: &mut RunReport,
    ) {
        for step in &sched.steps {
            let f = k_factor.get(&step.head).copied().unwrap_or(1.0);
            let x = step.x();
            let y = step.y();
            let xe = x as f64 * f;
            let step_ns = if overlap {
                f64::max(c.k_dt_ns * xe, c.q_arr_ns * y as f64)
                    + f64::max(c.k_comp_ns * xe, c.q_dt_ns * y as f64)
            } else {
                (c.k_dt_ns + c.k_comp_ns) * xe + (c.q_dt_ns + c.q_arr_ns) * y as f64
            };
            rep.latency_ns += step_ns;
            rep.compute_busy_ns += c.k_comp_ns * xe;
            rep.mac_pj += x as f64 * step.active_q as f64 * c.k_mac_per_row_pj;
            rep.k_fetch_pj += xe
                * (fresh_k_frac * c.k_fetch_dram_pj
                    + (1.0 - fresh_k_frac) * c.k_fetch_buf_pj
                    + c.k_dt_pj);
            rep.q_load_pj += y as f64 * (c.q_dt_pj + c.q_arr_pj);
            rep.k_vec_ops += x;
            rep.q_loads += y;
            rep.selected_pairs += step.selected_macs;
            rep.steps += 1;
        }
    }

    fn index_cost_pj(cim: &CimConfig, n: usize, index_bits: usize) -> f64 {
        let c = cim.op_costs();
        let frac = index_bits as f64 / cim.precision_bits as f64;
        (n * n) as f64 * c.k_mac_per_row_pj * frac / 2.0
    }

    pub fn run_dense(masks: &[SelectiveMask], cim: &CimConfig) -> RunReport {
        let c = cim.op_costs();
        let cap = cim.q_capacity();
        let plans: Vec<HeadPlan> = masks
            .iter()
            .enumerate()
            .map(|(h, m)| HeadPlan::build(h, m.clone(), m.n() / 2, 0))
            .collect();
        let sched = schedule_sequential(&plans, false);
        let factors: HashMap<usize, f64> = masks
            .iter()
            .enumerate()
            .map(|(h, m)| {
                let order: Vec<usize> = (0..m.n()).collect();
                let uses = chunked_k_uses_ref(m, &order, cap, true);
                (h, uses as f64 / m.n() as f64)
            })
            .collect();
        let mut rep = RunReport::default();
        accumulate(&sched, &c, false, 1.0, &factors, &mut rep);
        rep
    }

    pub fn run_gated(
        masks: &[SelectiveMask],
        cim: &CimConfig,
        opts: EngineOpts,
    ) -> RunReport {
        let c = cim.op_costs();
        let n = masks[0].n();
        let theta = (n as f64 * opts.theta_frac) as usize;
        let plans: Vec<HeadPlan> = masks
            .iter()
            .enumerate()
            .map(|(h, m)| HeadPlan::build(h, m.clone(), theta, opts.seed))
            .collect();
        let sched = schedule_sequential(&plans, true);
        let cap = cim.q_capacity();
        let factors: HashMap<usize, f64> = masks
            .iter()
            .enumerate()
            .map(|(h, m)| {
                let order: Vec<usize> = (0..m.n()).collect();
                let uses = chunked_k_uses_ref(m, &order, cap, false);
                (h, uses as f64 / m.n() as f64)
            })
            .collect();
        let mut rep = RunReport::default();
        accumulate(&sched, &c, false, 1.0, &factors, &mut rep);
        rep.mac_pj = sched.total_selected_macs() as f64 * c.k_mac_per_row_pj;
        for m in masks {
            rep.index_pj += index_cost_pj(cim, m.n(), opts.index_bits);
        }
        rep
    }

    pub fn run_sata(
        masks: &[SelectiveMask],
        cim: &CimConfig,
        rtl: &SchedRtl,
        opts: EngineOpts,
    ) -> RunReport {
        let c = cim.op_costs();
        let n = masks[0].n();
        let mut rep = RunReport::default();

        match opts.sf {
            None => {
                let theta = (n as f64 * opts.theta_frac) as usize;
                let cap = cim.q_capacity();
                let plans: Vec<HeadPlan> = masks
                    .iter()
                    .enumerate()
                    .map(|(h, m)| HeadPlan::build(h, m.clone(), theta, opts.seed))
                    .collect();
                let sched = schedule_sata(&plans);
                let factors: HashMap<usize, f64> = plans
                    .iter()
                    .map(|p| {
                        let mut order = p.class.major_queries();
                        order.extend(p.class.minor_queries());
                        let uses = chunked_k_uses_ref(&p.mask, &order, cap, false);
                        (p.head, uses as f64 / p.mask.n() as f64)
                    })
                    .collect();
                accumulate(&sched, &c, true, 1.0, &factors, &mut rep);
                for p in &plans {
                    let sc = rtl.schedule_cost(p.mask.n(), p.class.decrements);
                    rep.sched_pj += sc.energy_pj;
                }
                let per_head_ns = rep.latency_ns / masks.len() as f64;
                for p in &plans {
                    rep.latency_ns +=
                        per_head_ns * rtl.latency_overhead(p.mask.n(), cim.dk, per_head_ns);
                }
            }
            Some(sf) => {
                let mut carry_q: usize = 0;
                for (h, m) in masks.iter().enumerate() {
                    let n_h = m.n();
                    let ts = schedule_tiled(m, sf, opts.theta_frac, opts.seed ^ h as u64);

                    for step in &ts.schedule.steps {
                        rep.mac_pj +=
                            step.x() as f64 * step.active_q as f64 * c.k_mac_per_row_pj;
                        rep.selected_pairs += step.selected_macs;
                    }

                    let folds = n_h.div_ceil(sf);
                    let mut live_per_kf = vec![0usize; folds];
                    let mut live_total = 0usize;
                    for k in 0..n_h {
                        if m.col_popcount(k) > 0 {
                            live_per_kf[k / sf] += 1;
                            live_total += 1;
                        }
                    }

                    let y_total = if h == 0 { n_h } else { carry_q };
                    let mut y_left = y_total;
                    for (i, &x) in live_per_kf.iter().enumerate() {
                        let remaining = (folds - i).max(1);
                        let y = y_left.div_ceil(remaining).min(y_left);
                        y_left -= y;
                        let xe = x as f64;
                        rep.latency_ns += f64::max(c.k_dt_ns * xe, c.q_arr_ns * y as f64)
                            + f64::max(c.k_comp_ns * xe, c.q_dt_ns * y as f64);
                        rep.compute_busy_ns += c.k_comp_ns * xe;
                        rep.steps += 1;
                    }
                    carry_q = n_h;

                    rep.k_fetch_pj += live_total as f64 * (c.k_fetch_dram_pj + c.k_dt_pj);
                    rep.q_load_pj += n_h as f64 * (c.q_dt_pj + c.q_arr_pj);
                    rep.k_vec_ops += live_total;
                    rep.q_loads += n_h;

                    for t in &ts.tiles {
                        let msize = t.global_q.len().max(t.global_k.len()).max(1);
                        rep.sched_pj += rtl.schedule_cost(msize, 1).energy_pj;
                    }
                    let head_ns = live_total as f64 * (c.k_dt_ns + c.k_comp_ns);
                    rep.latency_ns += head_ns
                        * rtl.latency_overhead(sf.min(n_h), cim.dk, head_ns.max(1e-9));
                }
            }
        }

        for m in masks {
            rep.index_pj += index_cost_pj(cim, m.n(), opts.index_bits);
        }
        rep
    }
}

#[test]
fn golden_backend_ports_match_prerefactor_flows_on_ttst() {
    // The acceptance contract: per-flow RunReports (and hence gains) for
    // the TTST workload are bitwise-identical to the pre-refactor `run_*`.
    let spec = WorkloadSpec::ttst();
    let rtl = SchedRtl::tsmc65();
    let cim = CimConfig::default_65nm(spec.dk);
    for seed in [1u64, 7, 42] {
        let t = gen_trace(&spec, seed);
        let opts = EngineOpts { sf: spec.sf, ..Default::default() };

        let dense_new = run_dense(&t.heads, &cim);
        let dense_old = legacy::run_dense(&t.heads, &cim);
        assert_eq!(dense_new, dense_old, "dense diverged");

        let gated_new = run_gated(&t.heads, &cim, opts);
        let gated_old = legacy::run_gated(&t.heads, &cim, opts);
        assert_eq!(gated_new, gated_old, "gated diverged");

        let sata_new = run_sata(&t.heads, &cim, &rtl, opts);
        let sata_old = legacy::run_sata(&t.heads, &cim, &rtl, opts);
        assert_eq!(sata_new, sata_old, "sata diverged");

        let g_new = gains(&dense_new, &sata_new);
        let g_old = gains(&dense_old, &sata_old);
        assert!(g_new.throughput == g_old.throughput, "throughput gain diverged");
        assert!(g_new.energy_eff == g_old.energy_eff, "energy gain diverged");
    }
}

#[test]
fn golden_backend_ports_match_prerefactor_tiled_flow() {
    // Same contract for the tiled (S_f) path on the tiled Table-I rows.
    let rtl = SchedRtl::tsmc65();
    for spec in [WorkloadSpec::drsformer(), WorkloadSpec::kvt_deit_tiny()] {
        let cim = CimConfig::default_65nm(spec.dk);
        let t = gen_trace(&spec, 5);
        let opts = EngineOpts { sf: spec.sf, ..Default::default() };
        assert!(opts.sf.is_some());
        let new = run_sata(&t.heads, &cim, &rtl, opts);
        let old = legacy::run_sata(&t.heads, &cim, &rtl, opts);
        assert_eq!(new, old, "{}: tiled sata diverged", spec.name);
    }
}

#[test]
fn cim_substrate_path_is_bitwise_golden_across_workloads() {
    // The substrate tentpole's acceptance contract: routing execution
    // through `engine::substrate` must not move one bit of the CIM path —
    // pinned against both `run_planned` and the retained pre-refactor
    // legacy implementations, for every Table-I workload.
    let rtl = SchedRtl::tsmc65();
    for spec in WorkloadSpec::all_paper() {
        let t = gen_trace(&spec, 11);
        let sys = SystemConfig::for_workload(&spec);
        let sub = (substrate::by_name("cim").unwrap().build)(&sys, spec.dk);
        let cim = CimConfig::default_65nm(spec.dk);
        let opts = EngineOpts { sf: spec.sf, ..Default::default() };
        let plans = PlanSet::build(&t.heads, opts);
        for b in backend::all() {
            let via = b.run_on(&plans, &*sub);
            let direct = b.run_planned(&plans, &cim, &rtl);
            assert_eq!(via, direct, "{}@cim diverged ({})", b.name(), spec.name);
        }
        // Transitively: substrate path == the seed's free functions.
        assert_eq!(
            backend::DENSE.run_on(&plans, &*sub),
            legacy::run_dense(&t.heads, &cim),
            "{}: dense golden",
            spec.name
        );
        assert_eq!(
            backend::SATA.run_on(&plans, &*sub),
            legacy::run_sata(&t.heads, &cim, &rtl, opts),
            "{}: sata golden",
            spec.name
        );
    }
}

#[test]
fn every_flow_runs_on_every_substrate_across_workloads() {
    // Substrate-generic execution: same PlanSet, same FlowSchedule, both
    // hardware models — all seven flows, all four Table-I workloads
    // (whole-head and tiled schedule shapes).
    for spec in WorkloadSpec::all_paper() {
        let t = gen_trace(&spec, 7);
        let sys = SystemConfig::for_workload(&spec);
        let opts = EngineOpts { sf: spec.sf, ..Default::default() };
        let plans = PlanSet::build(&t.heads, opts);
        let want: usize = t.heads.iter().map(|m| m.total_selected()).sum();
        let n = t.heads[0].n();
        for sspec in &substrate::SUBSTRATES {
            let sub = (sspec.build)(&sys, spec.dk);
            for b in backend::all() {
                let rep = b.run_on(&plans, &*sub);
                let tag = format!("{} {}@{}", spec.name, b.name(), sspec.name);
                assert!(rep.latency_ns > 0.0, "{tag}: zero latency");
                assert!(rep.total_pj() > 0.0, "{tag}: zero energy");
                assert!(
                    rep.utilization() > 0.0 && rep.utilization() <= 1.0 + 1e-12,
                    "{tag}: utilization {}",
                    rep.utilization()
                );
                if b.name() == "dense" {
                    assert_eq!(rep.selected_pairs, t.heads.len() * n * n, "{tag}");
                } else {
                    assert_eq!(rep.selected_pairs, want, "{tag}: selected pairs");
                }
            }
        }
    }
}

#[test]
fn all_seven_flows_resolve_and_run_on_ttst() {
    let spec = WorkloadSpec::ttst();
    let t = gen_trace(&spec, 2);
    let cim = CimConfig::default_65nm(spec.dk);
    let rtl = SchedRtl::tsmc65();
    let plans = PlanSet::build(&t.heads, EngineOpts::default());
    let want: usize = t.heads.iter().map(|m| m.total_selected()).sum();
    let names = backend::flow_names();
    assert_eq!(names.len(), 7);
    for name in names {
        let b = backend::by_name(name).expect(name);
        let rep = b.run_planned(&plans, &cim, &rtl);
        assert!(rep.latency_ns > 0.0, "{name}: zero latency");
        assert!(rep.total_pj() > 0.0, "{name}: zero energy");
        if name != "dense" {
            assert_eq!(rep.selected_pairs, want, "{name}: selected pairs");
        }
    }
}

#[test]
fn residency_holds_for_every_registered_backend() {
    // Extends the SATA-only residency property: every query that selects a
    // MAC'd key must be resident, for *every* backend in the registry,
    // whole-head and tiled.
    check("registry-wide residency", 6, |rng| {
        let n = 8 + rng.gen_range(40);
        let k = 1 + rng.gen_range(n / 2);
        let heads = 1 + rng.gen_range(3);
        let masks: Vec<SelectiveMask> =
            (0..heads).map(|_| SelectiveMask::random_topk(n, k, rng)).collect();
        for sf in [None, Some(4 + rng.gen_range(n / 2))] {
            let opts = EngineOpts { sf, ..Default::default() };
            let plans = PlanSet::build(&masks, opts);
            for b in backend::all() {
                let sched = b.schedule(&plans);
                sched
                    .validate(&plans)
                    .map_err(|e| format!("{} (sf={sf:?}): {e}", b.name()))?;
            }
        }
        Ok(())
    });
}

#[test]
fn full_pipeline_all_paper_workloads() {
    let rtl = SchedRtl::tsmc65();
    for spec in WorkloadSpec::all_paper() {
        let t = gen_trace(&spec, 3);
        let cim = CimConfig::default_65nm(spec.dk);
        let dense = run_dense(&t.heads, &cim);
        let gated = run_gated(&t.heads, &cim, EngineOpts::default());
        let sata =
            run_sata(&t.heads, &cim, &rtl, EngineOpts { sf: spec.sf, ..Default::default() });
        // SATA must beat dense on both axes; gated saves energy vs dense.
        let g = gains(&dense, &sata);
        assert!(g.throughput > 1.0, "{}: {:.2}", spec.name, g.throughput);
        assert!(g.energy_eff > 1.0, "{}: {:.2}", spec.name, g.energy_eff);
        assert!(gated.total_pj() < dense.total_pj(), "{}", spec.name);
    }
}

#[test]
fn schedule_correctness_on_generated_traces() {
    check("generated-trace schedule correctness", 10, |rng| {
        let spec = WorkloadSpec::drsformer();
        let t = gen_trace(&spec, rng.next_u64());
        let plans: Vec<HeadPlan> = t
            .heads
            .iter()
            .enumerate()
            .map(|(h, m)| HeadPlan::build(h, m.clone(), m.n() / 2, 1))
            .collect();
        let s = schedule_sata(&plans);
        validate(&plans, &s)
    });
}

#[test]
fn trace_roundtrip_preserves_engine_results() {
    let spec = WorkloadSpec::ttst();
    let t = gen_trace(&spec, 9);
    let dir = std::env::temp_dir().join("sata_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ttst.json");
    t.save(&path).unwrap();
    let loaded = MaskTrace::load(&path).unwrap();
    let cim = CimConfig::default_65nm(spec.dk);
    let a = run_dense(&t.heads, &cim);
    let b = run_dense(&loaded.heads, &cim);
    assert_eq!(a.latency_ns, b.latency_ns);
    assert_eq!(a.total_pj(), b.total_pj());
    std::fs::remove_file(path).ok();
}

#[test]
fn coordinator_end_to_end_with_mixed_workloads() {
    let sys = SystemConfig::default();
    let coord = Coordinator::new(2, 4, sys);
    let mut id = 0;
    for spec in [WorkloadSpec::ttst(), WorkloadSpec::drsformer()] {
        for t in gen_traces(&spec, 2, 3) {
            coord.submit(Job::new(id, t, spec.sf)).unwrap();
            id += 1;
        }
    }
    let (results, metrics) = coord.drain();
    assert_eq!(results.len(), 4);
    assert!(results.iter().all(|r| r.is_ok()));
    assert!(metrics.mean_throughput_gain > 1.0);
    // four distinct traces → four cold plans, zero hits
    assert_eq!(metrics.cache_misses, 4);
    assert!(metrics.wall_p99_ns >= metrics.wall_p50_ns);
}

#[test]
fn cache_hit_execution_is_bitwise_identical_to_cold_plan_for_every_flow() {
    // The plan-cache correctness contract pinning the serve acceptance
    // criterion: executing any registered flow from a cached (hit-path)
    // PlanSet is bitwise identical to executing it from a freshly built
    // (cold-path) one, across the Table-I workloads.
    let rtl = SchedRtl::tsmc65();
    check("cache-hit == cold-plan execution", 6, |rng| {
        let specs = WorkloadSpec::all_paper();
        let spec = &specs[rng.gen_range(specs.len())];
        let t = gen_trace(spec, rng.next_u64());
        let opts = EngineOpts { sf: spec.sf, ..Default::default() };
        let cim = CimConfig::default_65nm(spec.dk);
        let cache = PlanCache::new(8, 2);
        let key = PlanSet::fingerprint_for(&t.heads, opts);
        let (_, warm_hit) =
            cache.get_or_build(key, || PlanSet::build(&t.heads, opts));
        if warm_hit {
            return Err("first lookup must miss".into());
        }
        let (cached, hit) =
            cache.get_or_build(key, || PlanSet::build(&t.heads, opts));
        if !hit {
            return Err("second lookup must hit".into());
        }
        let cold = PlanSet::build(&t.heads, opts);
        for b in backend::all() {
            let from_cache = b.run_planned(&cached, &cim, &rtl);
            let from_cold = b.run_planned(&cold, &cim, &rtl);
            if from_cache != from_cold {
                return Err(format!("{}: hit path diverged ({})", b.name(), spec.name));
            }
        }
        Ok(())
    });
}

#[test]
fn fingerprints_never_collide_across_table1_masks() {
    // Distinct masks must get distinct fingerprints over the Table-I
    // workloads (the plan cache would otherwise serve wrong plans).
    let mut seen: std::collections::HashMap<u64, SelectiveMask> =
        std::collections::HashMap::new();
    let mut distinct = 0usize;
    for spec in WorkloadSpec::all_paper() {
        for t in gen_traces(&spec, 8, 0xC0FFEE) {
            for m in t.heads {
                match seen.get(&m.fingerprint()) {
                    Some(prev) => assert_eq!(
                        prev, &m,
                        "{}: two distinct masks share a fingerprint",
                        spec.name
                    ),
                    None => {
                        seen.insert(m.fingerprint(), m);
                        distinct += 1;
                    }
                }
            }
        }
    }
    assert!(distinct > 200, "only {distinct} distinct masks sampled");
    // Trace-level fingerprints must also separate the workloads.
    let fps: Vec<u64> = WorkloadSpec::all_paper()
        .iter()
        .map(|spec| gen_trace(spec, 1).fingerprint())
        .collect();
    let mut uniq = fps.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), fps.len());
}

#[test]
fn malformed_trace_files_are_rejected_not_panicking() {
    for bad in [
        "",
        "{",
        r#"{"n": 0, "heads": []}"#,
        r#"{"n": 4, "heads": [[[9]]]}"#, // wrong row count
    ] {
        if let Ok(j) = Json::parse(bad) {
            assert!(MaskTrace::from_json(&j).is_err(), "accepted: {bad}");
        }
    }
}

#[test]
fn engine_is_deterministic_given_seed() {
    let spec = WorkloadSpec::kvt_deit_tiny();
    let t = gen_trace(&spec, 4);
    let cim = CimConfig::default_65nm(spec.dk);
    let rtl = SchedRtl::tsmc65();
    let opts = EngineOpts { sf: spec.sf, seed: 77, ..Default::default() };
    let a = run_sata(&t.heads, &cim, &rtl, opts);
    let b = run_sata(&t.heads, &cim, &rtl, opts);
    assert_eq!(a.latency_ns, b.latency_ns);
    assert_eq!(a.total_pj(), b.total_pj());
    assert_eq!(a.steps, b.steps);
}
