//! Tier-1 self-test for the `lint` subsystem (`sata lint`).
//!
//! Two halves:
//!
//! * the **live tree lints clean** — the panic/index/lock/waiver/drift
//!   families find nothing in the repo as committed, and the waiver
//!   count stays within the global budget;
//! * the **fixture corpus trips every family** — a mini repo root
//!   under `tests/lint_fixtures/` seeds one of each violation class,
//!   and each must surface as a finding (so a lint that silently stops
//!   firing fails the build, not just a lint that over-fires).

use std::path::{Path, PathBuf};

use sata::analysis::{run_lint, Family, Finding, LintReport};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn fixture_report() -> LintReport {
    run_lint(&repo_root().join("rust/tests/lint_fixtures"))
}

/// Assert some finding of `family` anchored to a file containing
/// `file_part` mentions `msg_part`.
fn assert_finding(report: &LintReport, family: Family, file_part: &str, msg_part: &str) {
    assert!(
        report.findings.iter().any(|f| f.family == family
            && f.file.contains(file_part)
            && f.message.contains(msg_part)),
        "expected a [{family}] finding in *{file_part}* mentioning {msg_part:?};\ngot:\n{}",
        report.render()
    );
}

#[test]
fn live_tree_is_lint_clean() {
    let report = run_lint(&repo_root());
    assert!(
        report.is_clean(),
        "the live tree must lint clean; findings:\n{}",
        report.render()
    );
    assert!(
        report.waivers_used <= report.waiver_budget,
        "waivers in use ({}) exceed the budget ({})",
        report.waivers_used,
        report.waiver_budget
    );
    assert!(
        report.files_scanned > 30,
        "suspiciously few files scanned ({}) — lint root miswired?",
        report.files_scanned
    );
}

#[test]
fn fixture_trips_panic_and_index_and_honours_waivers() {
    let report = fixture_report();
    assert!(!report.is_clean(), "fixture corpus must not lint clean");
    assert_finding(&report, Family::Panic, "coordinator/mod.rs", ".unwrap()");
    assert_finding(&report, Family::Index, "coordinator/mod.rs", "indexing");
    // The waived `xs[3]` consumed exactly one waiver, and no index
    // finding lands on the waived line.
    assert_eq!(report.waivers_used, 1, "exactly the waived site consumes a waiver");
    let src = std::fs::read_to_string(
        repo_root().join("rust/tests/lint_fixtures/rust/src/coordinator/mod.rs"),
    )
    .expect("fixture source");
    let waived_line = 1 + src
        .lines()
        .position(|l| l.contains("lint: allow(index"))
        .expect("waived site present")
        + 1; // the waiver comment sits directly above the indexing line
    let on_waived: Vec<&Finding> = report
        .findings
        .iter()
        .filter(|f| f.family == Family::Index && f.line == waived_line)
        .collect();
    assert!(on_waived.is_empty(), "waived line still flagged: {on_waived:?}");
}

#[test]
fn fixture_trips_waiver_bookkeeping() {
    let report = fixture_report();
    assert_finding(&report, Family::Waiver, "coordinator/mod.rs", "stale waiver");
    assert_finding(&report, Family::Waiver, "coordinator/mod.rs", "unknown family");
}

#[test]
fn fixture_trips_lock_discipline() {
    let report = fixture_report();
    assert_finding(&report, Family::Lock, "coordinator/mod.rs", "lock order");
    assert_finding(&report, Family::Lock, "coordinator/mod.rs", "send");
    assert_finding(&report, Family::Lock, "coordinator/mod.rs", "lock-order manifest");
    // The work-stealing pool's deque classes are ordered too: taking a
    // worker deque while parked on the pool signal is an inversion.
    assert_finding(
        &report,
        Family::Lock,
        "coordinator/mod.rs",
        "`worker_deque` while `pool_signal`",
    );
    // Crash-tolerance classes: the checkpoint writer outranks session
    // parts (`Coordinator::checkpoint` nests writer → registry →
    // parts), and the replay-log sink is innermost of all.
    assert_finding(
        &report,
        Family::Lock,
        "coordinator/mod.rs",
        "`ckpt_writer` while `parts`",
    );
    assert_finding(
        &report,
        Family::Lock,
        "coordinator/mod.rs",
        "`fault_plan` while `replay_log`",
    );
}

#[test]
fn fixture_trips_every_drift_check() {
    let report = fixture_report();
    // Snapshot family: missing baseline, bench absent from CI, orphan.
    assert_finding(&report, Family::Drift, "benches/ghost.rs", "is not committed");
    assert_finding(&report, Family::Drift, "benches/ghost.rs", "--bench ghost");
    assert_finding(&report, Family::Drift, "BENCH_orphan.json", "orphaned snapshot");
    // CLI family: usage/table/README disagreement.
    assert_finding(&report, Family::Drift, "main.rs", "--ghost-flag");
    assert_finding(&report, Family::Drift, "main.rs", "--hidden");
    assert_finding(&report, Family::Drift, "main.rs", "`phantom` is absent");
    assert_finding(&report, Family::Drift, "README.md", "--frobnicate");
    // Doc paths and registry names.
    assert_finding(&report, Family::Drift, "README.md", "src/ghost.rs");
    assert_finding(&report, Family::Drift, "DESIGN.md", "`systolic`");
}
