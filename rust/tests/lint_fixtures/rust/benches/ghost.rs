//! Lint fixture: a bench that emits a snapshot nothing commits and CI
//! never smoke-runs. Test data only — never compiled.

fn main() {
    let mut b = Bench::new();
    b.run("ghost.step", || {});
    b.emit_snapshot("ghost").expect("emit");
}
