//! Lint fixture: a hot-path module seeded with one of every violation
//! the `panic`, `index`, `lock`, and `waiver` families must catch.
//! This tree is test data for `tests/lint.rs` — it is never compiled.

use std::sync::{mpsc, Mutex};

/// A shard-shaped struct so lock receivers classify like the real ones.
pub struct Shard {
    pub shard: Mutex<Vec<u64>>,
    pub job_tx: Mutex<mpsc::Sender<u64>>,
    pub mystery: Mutex<u64>,
}

pub fn seeded_unwrap(v: Option<u64>) -> u64 {
    v.unwrap()
}

pub fn seeded_index(xs: &[u64]) -> u64 {
    xs[3]
}

pub fn waived_index(xs: &[u64]) -> u64 {
    // lint: allow(index, "fixture invariant: callers pass four elements")
    xs[3]
}

// lint: allow(panic, "stale: nothing on the covered line can panic")
pub fn stale_waiver_site() -> u64 {
    7
}

// lint: allow(frobnicate, "no such lint family")
pub fn unknown_family_site() -> u64 {
    8
}

pub fn inverted_order(s: &Shard) {
    let _shard = lock_recover(&s.shard, "fixture shard");
    let _tx = lock_recover(&s.job_tx, "fixture intake under shard");
}

pub fn send_under_shard_lock(s: &Shard, tx: &mpsc::Sender<u64>) {
    let _shard = lock_recover(&s.shard, "fixture shard");
    tx.send(1).ok();
}

pub fn unclassified_lock(s: &Shard) {
    let _m = lock_recover(&s.mystery, "not in the manifest");
}

/// Work-stealing-pool shape: deque receivers classify like the real
/// `util::deque::ExecPool` fields.
pub struct StealPool {
    pub injector: Mutex<Vec<u64>>,
    pub deques: Vec<Mutex<Vec<u64>>>,
    pub signal: Mutex<()>,
}

pub fn inverted_deque_order(p: &StealPool) {
    let _parked = lock_recover(&p.signal, "fixture pool signal");
    let _steal = lock_recover(&p.deques[0], "fixture deque under signal");
}

/// Crash-tolerance shape: checkpoint writer, live-session registry,
/// per-session parts, fault plan, and replay-log sink classify like the
/// real coordinator / `util::fault` / `util::replay` fields.
pub struct CrashState {
    pub ckpt: Mutex<()>,
    pub live: Mutex<Vec<u64>>,
    pub parts: Mutex<Vec<u64>>,
    pub fault_plan: Mutex<u64>,
    pub replay_log: Mutex<Vec<u64>>,
}

pub fn inverted_checkpoint_order(s: &CrashState) {
    let _parts = lock_recover(&s.parts, "fixture session parts");
    let _writer = lock_recover(&s.ckpt, "fixture checkpoint writer under parts");
}

pub fn inverted_replay_order(s: &CrashState) {
    let _log = lock_recover(&s.replay_log, "fixture replay log");
    let _plan = lock_recover(&s.fault_plan, "fixture fault plan under log");
}
