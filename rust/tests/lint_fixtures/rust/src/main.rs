//! Lint fixture: a CLI surface whose help text, parser table, and
//! README have drifted apart. Test data only — never compiled.

const USAGE: &str = "fixture CLI
usage: fixture <run> [flags]
  run: [--jobs N] [--ghost-flag X]";

const SUBCOMMANDS: &[(&str, &[&str])] = &[
    ("run", &["jobs", "hidden"]),
    ("phantom", &[]),
];

fn main() {
    println!("{USAGE} {SUBCOMMANDS:?}");
}
