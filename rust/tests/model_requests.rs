//! Model-request path acceptance tests: a 1-layer `ModelTrace` through
//! the coordinator is **bitwise identical** (cycles, energy, traffic) to
//! the pre-refactor single-trace path for all seven flows on both
//! substrates; `gen_model`'s `rho` knob produces valid masks with
//! monotone inter-layer overlap; multi-layer requests fold correctly and
//! hit the per-layer plan cache.

use sata::config::{SystemConfig, WorkloadSpec};
use sata::coordinator::{Coordinator, CoordinatorConfig, Job};
use sata::engine::backend::{self, PlanSet};
use sata::engine::{substrate, EngineOpts, RunReport};
use sata::model::report::ModelReport;
use sata::model::ModelTrace;
use sata::trace::synth::{gen_model, gen_trace};
use sata::trace::TraceDir;
use sata::util::prop::check;

/// The pre-model single-trace execution path: plan the bare `MaskTrace`
/// once, run one flow on one substrate. This is exactly what the
/// coordinator's execute worker did per job before the refactor (pinned
/// transitively golden against the seed's free functions by
/// `tests/integration.rs`).
fn legacy_single_trace_reports(
    spec: &WorkloadSpec,
    seed: u64,
    substrate_name: &str,
) -> Vec<(String, RunReport)> {
    let t = gen_trace(spec, seed);
    let sys = SystemConfig::for_workload(spec);
    // The exact opts the coordinator's plan worker builds.
    let opts = EngineOpts {
        sf: spec.sf,
        theta_frac: sys.theta_frac,
        seed: sys.seed,
        ..Default::default()
    };
    let plans = PlanSet::build(&t.heads, opts);
    let sub = (substrate::by_name(substrate_name).unwrap().build)(&sys, spec.dk);
    backend::all()
        .into_iter()
        .map(|b| (b.name().to_string(), b.run_on(&plans, &*sub)))
        .collect()
}

#[test]
fn one_layer_model_is_bitwise_identical_to_single_trace_path_everywhere() {
    // The refactor's golden contract: for every Table-I workload, every
    // registered flow, and both substrates, a 1-layer ModelTrace served
    // through the model-request coordinator reproduces the pre-refactor
    // single-trace reports bit for bit — total AND per-layer.
    for spec in WorkloadSpec::all_paper() {
        let seed = 13;
        let flow_names: Vec<String> =
            backend::flow_names().iter().map(|s| s.to_string()).collect();
        for sspec in &substrate::SUBSTRATES {
            let expected = legacy_single_trace_reports(&spec, seed, sspec.name);

            let sys = SystemConfig::for_workload(&spec);
            let coord = Coordinator::new(2, 4, sys);
            let trace = gen_trace(&spec, seed); // wraps into a 1-layer model
            coord
                .submit(
                    Job::with_flows(0, trace, spec.sf, flow_names.clone())
                        .on_substrate(sspec.name),
                )
                .unwrap();
            let (results, _) = coord.drain();
            assert_eq!(results.len(), 1);
            let r = &results[0];
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(r.layers, 1);
            assert_eq!(r.flows.len(), expected.len());

            // Dense baseline matches the legacy dense run.
            let legacy_dense = &expected[0].1;
            assert_eq!(&r.dense.total, legacy_dense, "{}@{}", spec.name, sspec.name);
            for (fr, (name, legacy)) in r.flows.iter().zip(&expected) {
                assert_eq!(&fr.flow, name);
                let tag = format!("{} {}@{}", spec.name, name, sspec.name);
                assert_eq!(&fr.report.total, legacy, "{tag}: total diverged");
                assert_eq!(fr.report.n_layers(), 1, "{tag}");
                assert_eq!(&fr.report.layers[0], legacy, "{tag}: layer diverged");
            }
        }
    }
}

#[test]
fn multi_layer_request_folds_exactly_the_per_layer_runs() {
    // A model job's reports must equal running each layer standalone and
    // folding — no hidden cross-layer state in the execute path.
    let spec = WorkloadSpec::ttst();
    let m = gen_model(&spec, 3, 0.5, 21);
    let sys = SystemConfig::for_workload(&spec);
    let opts = EngineOpts {
        sf: spec.sf,
        theta_frac: sys.theta_frac,
        seed: sys.seed,
        ..Default::default()
    };
    for sspec in &substrate::SUBSTRATES {
        let sub = (sspec.build)(&sys, spec.dk);
        let expected = ModelReport::fold(
            m.layers
                .iter()
                .map(|l| {
                    let plans = PlanSet::build(&l.heads, opts);
                    backend::SATA.run_on(&plans, &*sub)
                })
                .collect(),
        );

        let coord = Coordinator::new(2, 4, SystemConfig::for_workload(&spec));
        coord
            .submit(Job::new(0, m.clone(), spec.sf).on_substrate(sspec.name))
            .unwrap();
        let (results, _) = coord.drain();
        let r = &results[0];
        assert!(r.is_ok(), "{:?}", r.error);
        assert_eq!(r.flows[0].report, expected, "{} diverged", sspec.name);
        assert!(r.flows[0].report.critical_layer().is_some());
    }
}

#[test]
fn correlated_model_requests_hit_the_plan_cache_across_layers() {
    // gen_model(rho) is the cross-layer-locality workload: higher rho →
    // strictly more per-layer plan-cache hits within one request.
    let spec = WorkloadSpec::kvt_deit_tiny();
    let layers = 5;
    let mut hits = Vec::new();
    for rho in [0.0, 0.5, 1.0] {
        let sys = SystemConfig::for_workload(&spec);
        let coord = Coordinator::with_config(
            sys,
            CoordinatorConfig { plan_workers: 1, exec_workers: 1, ..Default::default() },
        );
        coord
            .submit(Job::new(0, gen_model(&spec, layers, rho, 2), spec.sf))
            .unwrap();
        let (results, metrics) = coord.drain();
        assert!(results[0].is_ok());
        assert_eq!(metrics.cache_hits + metrics.cache_misses, layers);
        hits.push(metrics.cache_hits);
    }
    assert!(hits[0] < hits[1] && hits[1] < hits[2], "{hits:?}");
    assert_eq!(hits[0], 0, "rho=0 layers are independent");
    assert_eq!(hits[2], layers - 1, "rho=1 re-plans nothing after layer 0");
}

#[test]
fn gen_model_is_total_and_valid_over_random_rho_and_depth() {
    // Valid masks for all rho ∈ [0,1]: exact-TopK rows, duplicate-free,
    // JSON-reloadable, and servable end to end.
    check("gen_model valid + servable over rho", 8, |rng| {
        let spec = WorkloadSpec::ttst();
        let rho = rng.f64();
        let layers = 1 + rng.gen_range(4);
        let m = gen_model(&spec, layers, rho, rng.next_u64());
        for (l, t) in m.layers.iter().enumerate() {
            for h in &t.heads {
                for q in 0..h.n() {
                    if h.row_popcount(q) != spec.topk {
                        return Err(format!("layer {l}: row {q} not exact-K"));
                    }
                }
            }
        }
        let back = ModelTrace::from_json(&m.to_json())
            .map_err(|e| format!("reload failed: {e}"))?;
        if back.fingerprint() != m.fingerprint() {
            return Err("fingerprint changed across JSON roundtrip".into());
        }
        Ok(())
    });
}

#[test]
fn measured_overlap_is_monotone_in_rho_and_spans_the_range() {
    let spec = WorkloadSpec::drsformer();
    let grid = [0.0, 0.25, 0.5, 0.75, 1.0];
    let overlaps: Vec<f64> = grid
        .iter()
        .map(|&rho| gen_model(&spec, 5, rho, 17).inter_layer_overlap())
        .collect();
    for w in overlaps.windows(2) {
        assert!(w[1] >= w[0] - 0.03, "not monotone: {overlaps:?}");
    }
    assert!(overlaps[4] > overlaps[0] + 0.3, "no dynamic range: {overlaps:?}");
    assert!((overlaps[4] - 1.0).abs() < 1e-12);
}

#[test]
fn traces_dir_serves_mixed_single_layer_and_model_files_end_to_end() {
    // The serve shape over a directory mixing a bare single-layer trace,
    // a multi-layer model file, and a hostile file: good jobs complete
    // with the right layer counts, the bad file reports a per-file error.
    let dir = std::env::temp_dir().join("sata_mixed_corpus_test");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let spec = WorkloadSpec::ttst();
    gen_trace(&spec, 1).save(&dir.join("a_single.json")).unwrap();
    gen_model(&spec, 3, 0.8, 2).save(&dir.join("b_model.json")).unwrap();
    std::fs::write(
        dir.join("c_bad.json"),
        r#"{"layers": [{"n": 4, "heads": [[[777],[0],[1],[2]]]}]}"#,
    )
    .unwrap();

    let coord = Coordinator::new(2, 4, SystemConfig::for_workload(&spec));
    let mut id = 0;
    let mut file_errors = Vec::new();
    for (path, parsed) in TraceDir::open(&dir).unwrap() {
        match parsed {
            Ok(m) => {
                coord.submit(Job::new(id, m, spec.sf)).unwrap();
                id += 1;
            }
            Err(e) => file_errors.push((path, e)),
        }
    }
    let (results, metrics) = coord.drain();
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(results[0].layers, 1, "bare file = 1-layer request");
    assert_eq!(results[1].layers, 3, "model file keeps its depth");
    assert_eq!(metrics.jobs_done, 2);
    assert_eq!(metrics.layers_planned, 4);
    assert_eq!(file_errors.len(), 1);
    assert!(file_errors[0].1.contains("layer 0"), "{}", file_errors[0].1);
    assert!(file_errors[0].1.contains("out of range"), "{}", file_errors[0].1);
    std::fs::remove_dir_all(&dir).ok();
}
