//! Record/replay determinism: a `serve --record` log
//! ([`sata::coordinator::record`]) replays bitwise — result digests,
//! deterministic counters, and fired-fault counts all match — with and
//! without injected kills; and the sealed-log format
//! ([`sata::util::replay`]) rejects truncated or tampered logs with an
//! explicit error, never a panic and never a silently-wrong replay.

use sata::coordinator::record::{replay_lines, run_recorded, RecordSpec};
use sata::util::json::Json;
use sata::util::replay::{parse_log, read_log, write_log};

fn spec(kill_units: Vec<u64>) -> RecordSpec {
    RecordSpec {
        workload: "ttst".into(),
        jobs: 4,
        layers: 2,
        steps: 2,
        kappa: 0.7,
        rho: 0.4,
        seed: 11,
        flows: vec!["sata".into(), "dense".into()],
        substrate: "cim".into(),
        workers: 2,
        queue: "ws".into(),
        queue_cap: 8,
        retry_budget: 2,
        kill_units,
    }
}

#[test]
fn a_clean_recording_replays_bitwise_through_a_file() {
    let out = run_recorded(&spec(Vec::new())).expect("record");
    assert_eq!(out.results.len(), 4);
    assert!(out.results.iter().all(|r| r.is_ok()));
    // Round-trip the sealed text through disk, exactly like
    // `serve --record LOG` followed by `sata replay LOG`.
    let path = std::env::temp_dir().join("sata_replay_clean.log");
    write_log(&path, &out.log).expect("write");
    let lines = read_log(&path).expect("sealed log validates");
    std::fs::remove_file(&path).ok();
    let report = replay_lines(&lines).expect("structurally valid log");
    assert!(report.ok(), "clean replay diverged: {report:?}");
    assert_eq!(report.jobs, 4);
    assert_eq!(report.results_matched, 4);
    assert_eq!(report.faults_fired, (0, 0));
}

#[test]
fn a_disturbed_recording_replays_bitwise_including_its_faults() {
    // Two kills within the per-job budget: the recorded run retried
    // through them, and the replay re-injects the same ordinals.
    let out = run_recorded(&spec(vec![1, 2])).expect("record with faults");
    assert_eq!(out.faults_fired, 2);
    assert_eq!(out.metrics.worker_deaths, 2);
    assert_eq!(out.metrics.units_abandoned, 0);
    assert!(out.results.iter().all(|r| r.is_ok()));
    let lines = parse_log(&out.log).expect("sealed");
    let report = replay_lines(&lines).expect("valid");
    assert!(report.ok(), "disturbed replay diverged: {report:?}");
    assert_eq!(report.faults_fired, (2, 2));
}

#[test]
fn truncated_and_tampered_logs_error_explicitly() {
    let out = run_recorded(&spec(Vec::new())).expect("record");
    let lines_n = out.log.lines().count();

    // Truncated: the end trailer is gone.
    let truncated: String = out
        .log
        .lines()
        .take(lines_n - 1)
        .map(|l| format!("{l}\n"))
        .collect();
    let err = parse_log(&truncated).expect_err("must reject truncation");
    assert!(err.contains("no end trailer"), "got: {err}");

    // Truncated mid-payload but trailer kept: the count catches it.
    let gutted: String = out
        .log
        .lines()
        .enumerate()
        .filter(|(i, _)| *i != 1)
        .map(|(_, l)| format!("{l}\n"))
        .collect();
    let err = parse_log(&gutted).expect_err("must reject a missing line");
    assert!(err.contains("count"), "got: {err}");

    // Tampered: same line count, one byte of payload flipped.
    let tampered: String = out
        .log
        .lines()
        .enumerate()
        .map(|(i, l)| {
            if i == 0 {
                format!("{}\n", l.replace("\"ttst\"", "\"TTSL\""))
            } else {
                format!("{l}\n")
            }
        })
        .collect();
    assert_ne!(tampered, out.log, "the tamper must actually change a line");
    let err = parse_log(&tampered).expect_err("must reject tampering");
    assert!(err.contains("checksum mismatch"), "got: {err}");

    // Garbage is a parse error with a line number, not a panic.
    let err = parse_log("{\"kind\": \"config\"").expect_err("unparseable");
    assert!(err.contains("line 1"), "got: {err}");
}

#[test]
fn a_divergent_replay_is_reported_not_erred() {
    // Corrupt one recorded result digest *after* checksum validation —
    // the replay must run to completion and report the divergence
    // (exit-1 territory for `sata replay`), not fail structurally.
    let out = run_recorded(&spec(Vec::new())).expect("record");
    let mut lines = parse_log(&out.log).expect("sealed");
    let mut corrupted = false;
    for line in &mut lines {
        if line.get("kind").as_str() == Some("result") && !corrupted {
            if let Json::Obj(m) = line {
                m.insert("digest".into(), Json::str("0000000000000000"));
                corrupted = true;
            }
        }
    }
    assert!(corrupted, "log must contain result lines");
    let report = replay_lines(&lines).expect("still structurally valid");
    assert!(!report.ok(), "corrupted digest must diverge");
    assert_eq!(report.mismatched_ids.len(), 1, "{report:?}");
    assert_eq!(report.results_matched, 3, "{report:?}");
}

#[test]
fn recording_rejects_shapes_it_cannot_promise_to_replay() {
    // More kills than the retry budget: *which* job exhausts its budget
    // would race, so the recorder refuses up front.
    let err = run_recorded(&spec(vec![1, 2, 3]))
        .expect_err("over-budget kills are unreplayable");
    assert!(err.contains("retry budget"), "got: {err}");
}
